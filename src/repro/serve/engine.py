"""Multi-tenant serving engine whose job->submesh scheduler is MAGMA.

This is the paper's technique integrated as a first-class framework
feature, hardware-adapted to TPU pods (DESIGN.md §3):

  sub-accelerator  ->  TPU submesh (tp x dp slice of the pod)
  job              ->  (tenant, phase) unit: a prefill of a request batch,
                       or a decode window of T tokens
  system BW        ->  shared host->pod ingress (PCIe/DCN) that all
                       submeshes contend for
  job analysis     ->  TPU roofline cost model (costmodel.tpu): no-stall
                       latency = max(compute, HBM) term; required BW =
                       host-visible bytes / latency

The engine batches queued requests into dependency-free job groups,
profiles them against every submesh, runs MAGMA over the (selection x
priority) encoding, and returns the mapping + the BW-allocator timeline.
``schedule(..., execute=True)`` additionally runs the scheduled jobs for
real (smoke-size models on CPU; the same code path drives TPU submeshes
via jit) so tests can check output correctness, not just schedule quality.

Since the ``repro.stream`` service landed, the engine is a *client* of the
stream rather than a standalone code path: every device-resident method
is scheduled via ``StreamingScheduler.schedule_prepared`` (the engine's
TPU-roofline tables enter the admission queue as prepared scenarios and
ride the same compiled row executables as every sweep), which is
bit-identical to the old direct ``run_strategy`` call with the same seed
and budget.  Host-only methods (heuristics, RL) keep the host loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import M3E  # noqa: F401  (re-export convenience)
from repro.core.fitness import FitnessFn
from repro.core.job_analyzer import table_from_arrays
from repro.core.magma import SearchResult
from repro.core.bw_allocator import simulate_numpy
from repro.core.encoding import decode_to_lists
from repro.costmodel.tpu import TPUSubmesh, V5E
from repro.models import module
from repro.models.config import ModelConfig
from repro.models.registry import get_model, count_active_params


@dataclasses.dataclass
class Submesh:
    """One schedulable slice of the pod."""
    name: str
    tp: int
    dp: int = 1

    @property
    def cost(self) -> TPUSubmesh:
        return TPUSubmesh(self.name, tp=self.tp, dp=self.dp)


def default_submeshes() -> List[Submesh]:
    """A heterogeneous carving of one 256-chip pod: big TP slices for
    latency-critical prefill, small slices for decode — the TPU analogue of
    the paper's HB/LB heterogeneous cores."""
    return [Submesh("tp16_a", 16), Submesh("tp16_b", 16),
            Submesh("tp8_a", 8), Submesh("tp8_b", 8),
            Submesh("tp4_a", 4), Submesh("tp4_b", 4),
            Submesh("tp4_c", 4), Submesh("tp4_d", 4)]


@dataclasses.dataclass(frozen=True)
class TenantSLO:
    """Per-tenant service-level objective, forwarded to the stream's
    SLO-aware admission: ``priority`` is one of
    ``repro.stream.workloads.PRIORITY_CLASSES`` and ``deadline_s`` the
    scheduling-latency budget (admission -> schedule routed).  A job
    group spanning several tenants is scheduled at the STRICTEST member
    SLO — an urgent tenant's jobs must not wait because a batch tenant
    shares the group."""
    priority: str = "normal"
    deadline_s: Optional[float] = None

    def __post_init__(self):
        from repro.stream.workloads import PRIORITY_CLASSES
        if self.priority not in PRIORITY_CLASSES:
            raise ValueError(f"unknown priority {self.priority!r}; "
                             f"expected one of {PRIORITY_CLASSES}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0 or None, got "
                             f"{self.deadline_s}")


@dataclasses.dataclass
class Tenant:
    name: str
    cfg: ModelConfig
    params: object                  # value tree
    model: object = None
    slo: Optional[TenantSLO] = None  # None: normal priority, no deadline

    def __post_init__(self):
        if self.model is None:
            self.model = get_model(self.cfg)


@dataclasses.dataclass
class ServeJob:
    uid: int
    tenant: str
    phase: str                      # 'prefill' | 'decode'
    batch: int                      # requests in the job
    seq: int                        # prompt len (prefill) / ctx len (decode)
    tokens: int                     # tokens produced/processed
    flops: float = 0.0
    hbm_bytes: float = 0.0
    host_bytes: float = 0.0


def job_costs(cfg: ModelConfig, phase: str, batch: int, seq: int,
              tokens: int) -> Tuple[float, float, float]:
    """(flops, hbm_bytes, host_bytes) for one job, from the model config."""
    n_active = count_active_params(cfg)
    bpe = 2  # bf16
    if phase == "prefill":
        flops = 2.0 * n_active * batch * seq
        hbm = n_active * bpe + batch * seq * cfg.d_model * bpe
        host = batch * seq * 4 + batch * seq * cfg.d_model * bpe * 0.0 \
            + batch * 4  # token ids in, last-logit ids out
        if cfg.family in ("vlm", "encdec"):
            host += batch * seq * cfg.d_model * bpe  # embeddings cross PCIe
    else:
        flops = 2.0 * n_active * batch * tokens
        kv_heads = max(cfg.n_kv_heads, 1)
        kv = (2 * cfg.num_layers * batch * seq * kv_heads * cfg.hd * bpe
              if cfg.n_heads else
              cfg.num_layers * batch * cfg.inner * cfg.ssm_state * 4)
        hbm = tokens * (n_active * bpe + kv)
        host = batch * tokens * 2 * 4
    return float(flops), float(hbm), float(host)


class MultiTenantEngine:
    def __init__(self, tenants: Sequence[Tenant],
                 submeshes: Optional[Sequence[Submesh]] = None,
                 system_bw: float = 64e9, group_size: int = 64,
                 decode_window: int = 32, budget: int = 2_000,
                 method: str = "magma", seed: int = 0,
                 stream=None, memo=None, fleet=None):
        self.tenants = {t.name: t for t in tenants}
        self.submeshes = list(submeshes or default_submeshes())
        self.system_bw = float(system_bw)
        self.group_size = group_size
        self.decode_window = decode_window
        self.budget = budget
        self.method = method
        self.seed = seed
        self._uid = 0
        # the stream service this engine schedules through (shared so many
        # engines can feed one admission queue); lazily built when the
        # first device-resident method is scheduled
        self._stream = stream
        self._owns_stream = False
        # schedule memo (repro.memo.ScheduleMemo) consulted by the stream
        # at admission: a re-seen job group replays its stored mapping
        # bit-for-bit with no search; near-same groups warm-start.  Only
        # applies to the service this engine creates — an injected
        # ``stream`` keeps whatever memo it was built with.
        self.memo = memo
        # fleet-backed option: an injected ``repro.fleet.Fleet`` serves
        # device-resident methods instead of an in-process stream — the
        # prepared tables cross to a worker bit-exactly and the returned
        # schedule is bit-identical to the in-process path (the fleet
        # contract).  The fleet is the injector's to launch and close.
        self.fleet = fleet

    def stream_service(self):
        """The ``repro.stream.StreamingScheduler`` this engine is a client
        of (created on first use unless one was injected)."""
        if self._stream is None:
            from repro.stream import StreamConfig, StreamingScheduler
            # no trace analysis happens on this path (scenarios arrive
            # prepared), so a minimal analysis pool suffices
            self._stream = StreamingScheduler(
                budget=self.budget,
                stream=StreamConfig(analysis_workers=1),
                memo=self.memo)
            self._owns_stream = True
        return self._stream

    def close(self) -> None:
        """Shut down the stream service this engine created (an injected,
        shared service is the injector's to close)."""
        if self._owns_stream and self._stream is not None:
            self._stream.close()
            self._stream = None
            self._owns_stream = False

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- job construction -----------------------------------------------------
    def jobs_for_requests(self, requests: Sequence[Tuple[str, int, int]]
                          ) -> List[ServeJob]:
        """requests: (tenant, prompt_len, gen_len) -> prefill + decode jobs."""
        jobs: List[ServeJob] = []
        for tenant, prompt, gen in requests:
            cfg = self.tenants[tenant].cfg
            f, h, p = job_costs(cfg, "prefill", 1, prompt, prompt)
            jobs.append(ServeJob(self._uid, tenant, "prefill", 1, prompt,
                                 prompt, f, h, p))
            self._uid += 1
            done = 0
            while done < gen:
                w = min(self.decode_window, gen - done)
                ctx = prompt + done + w
                f, h, p = job_costs(cfg, "decode", 1, ctx, w)
                jobs.append(ServeJob(self._uid, tenant, "decode", 1, ctx, w,
                                     f, h, p))
                self._uid += 1
                done += w
        return jobs

    def slo_for(self, jobs: Sequence[ServeJob]) -> TenantSLO:
        """The strictest SLO across the tenants appearing in ``jobs``:
        highest priority class, smallest deadline.  Tenants without an
        SLO contribute the (normal, no-deadline) default."""
        from repro.stream.workloads import PRIORITY_CLASSES
        slos = [self.tenants[j.tenant].slo or TenantSLO()
                for j in jobs] or [TenantSLO()]
        priority = min((s.priority for s in slos),
                       key=PRIORITY_CLASSES.index)
        deadlines = [s.deadline_s for s in slos if s.deadline_s is not None]
        return TenantSLO(priority=priority,
                         deadline_s=min(deadlines) if deadlines else None)

    # -- analysis + scheduling --------------------------------------------------
    def analyze(self, jobs: Sequence[ServeJob]):
        """Job-analysis table over (job x submesh) from the TPU cost model.

        Carries an energy column (``TPUSubmesh.energy_j``: whole-slice
        board power x duration) so the serving tier can search energy and
        EDP objectives — a tp16 slice finishes a job ~4x faster than tp4
        but holds 4x the chips, a real latency/energy frontier.
        """
        G, A = len(jobs), len(self.submeshes)
        lat = np.zeros((G, A))
        bw = np.zeros((G, A))
        en = np.zeros((G, A))
        for g, job in enumerate(jobs):
            for a, sm in enumerate(self.submeshes):
                l, b = sm.cost.profile(job.flops, job.hbm_bytes,
                                       job.host_bytes)
                lat[g, a] = l
                bw[g, a] = b
                en[g, a] = sm.cost.energy_j(l)
        flops = np.array([j.flops for j in jobs])
        return table_from_arrays(lat, bw, flops, energy=en)

    def schedule(self, jobs: Sequence[ServeJob],
                 method: Optional[str] = None,
                 execute: bool = False,
                 prompts: Optional[Dict[int, np.ndarray]] = None) -> Dict:
        """Profile, search, and map ``jobs`` onto the submeshes.

        Device-resident methods go through the stream service (prepared
        scenario -> admission queue -> compiled row executable), which is
        bit-identical to a direct ``run_strategy`` with the same seed and
        budget; host-only methods run their own loops.  With
        ``execute=True`` the scheduled jobs also run for real in queue
        order (``prompts`` maps prefill-job uid -> token array) and the
        generated tokens come back under ``"outputs"``.
        """
        from repro.core.strategies import get_strategy, run_strategy
        if execute and prompts is None:
            raise ValueError("execute=True needs prompts "
                             "(prefill-job uid -> token array)")
        table = self.analyze(jobs)
        fit = FitnessFn(table, bw_sys=self.system_bw)
        method = method or self.method
        strategy = get_strategy(method)
        stream_res = None
        if strategy.device_resident:
            slo = self.slo_for(jobs)
            if self.fleet is not None:
                from repro.stream.service import PreparedScenario
                stream_res = self.fleet.run(prepared=[PreparedScenario(
                    fit=fit, seed=self.seed, budget=self.budget,
                    strategy=strategy, priority=slo.priority,
                    deadline_s=slo.deadline_s)])[0]
            else:
                stream_res = self.stream_service().schedule_prepared(
                    fit, seed=self.seed, budget=self.budget,
                    strategy=strategy,
                    priority=slo.priority, deadline_s=slo.deadline_s)
            res = stream_res.to_search_result()
        else:
            res: SearchResult = run_strategy(strategy, fit,
                                             budget=self.budget,
                                             seed=self.seed)
        local = decode_to_lists(res.best_accel, res.best_prio,
                                len(self.submeshes))
        makespan = simulate_numpy(local, table.lat, table.bw, self.system_bw)
        # map group-local job indices back to engine-global job uids
        queues = [[int(jobs[i].uid) for i in q] for q in local]
        out = {
            "result": res,
            "queues": queues,
            "local_queues": local,
            "makespan_s": float(makespan),
            "throughput_flops": table.total_flops / max(makespan, 1e-30),
            "table": table,
            "stream": stream_res,
        }
        if execute:
            out["outputs"] = self.execute(jobs, queues, prompts)
        return out

    def schedule_front(self, jobs: Sequence[ServeJob],
                       objectives: Sequence[str] = ("latency", "energy",
                                                    "edp"),
                       method: str = "nsga2") -> Dict:
        """Co-search several serving objectives at once -> the frontier.

        Same profile tables as :meth:`schedule` (the energy column comes
        from whole-slice board power), a vector ``ObjectiveSpec``, routed
        through ``stream_service().schedule_front`` under the job group's
        strictest tenant SLO.  Returns the ``ParetoFront`` plus, for each
        front point, the decoded queues and simulated makespan — the
        operator picks the latency/energy trade-off, every candidate
        already a complete schedule.
        """
        table = self.analyze(jobs)
        fit = FitnessFn(table, bw_sys=self.system_bw,
                        objective=tuple(objectives))
        slo = self.slo_for(jobs)
        front = self.stream_service().schedule_front(
            fit, seed=self.seed, budget=self.budget, strategy=method,
            priority=slo.priority, deadline_s=slo.deadline_s)
        points = []
        for k in range(len(front)):
            pt = front.point(k)
            local = decode_to_lists(pt["accel"], pt["prio"],
                                    len(self.submeshes))
            makespan = simulate_numpy(local, table.lat, table.bw,
                                      self.system_bw)
            points.append({
                "objectives": {n: pt[n] for n in front.names},
                "queues": [[int(jobs[i].uid) for i in q] for q in local],
                "makespan_s": float(makespan),
            })
        return {"front": front, "points": points, "table": table}

    # -- execution (functional correctness on the scheduled order) -------------
    def execute(self, jobs: Sequence[ServeJob], queues: List[List[int]],
                prompts: Dict[int, np.ndarray]) -> Dict[int, np.ndarray]:
        """Run the scheduled jobs for real, in per-submesh queue order.

        ``prompts``: prefill-job uid -> (1, prompt_len) token array.
        Returns uid -> generated token ids (greedy) for decode jobs.
        State (cache) is keyed per tenant-request chain."""
        outputs: Dict[int, np.ndarray] = {}
        chains: Dict[str, Dict] = {}
        by_uid = {j.uid: j for j in jobs}
        order = [uid for q in queues for uid in q]
        # execution must respect per-chain phase order; queue order decides
        # inter-chain interleaving (the scheduler's freedom)
        for uid in sorted(order, key=lambda u: u):
            job = by_uid[uid]
            tenant = self.tenants[job.tenant]
            model, cfg = tenant.model, tenant.cfg
            chain = chains.setdefault(job.tenant, {})
            if job.phase == "prefill":
                toks = jnp.asarray(prompts[uid])
                total = job.seq + sum(
                    j.tokens for j in jobs
                    if j.tenant == job.tenant and j.phase == "decode")
                logits, cache = model.prefill(tenant.params,
                                              {"tokens": toks}, total)
                chain["cache"] = cache
                chain["pos"] = job.seq
                chain["last"] = jnp.argmax(logits[:, -1], axis=-1)
            else:
                cache, pos = chain["cache"], chain["pos"]
                cur = chain["last"][:, None].astype(jnp.int32)
                outs = []
                for _ in range(job.tokens):
                    logits, cache = model.decode_step(tenant.params, cache,
                                                      cur, jnp.int32(pos))
                    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                    cur = cur.astype(jnp.int32)
                    outs.append(np.asarray(cur[:, 0]))
                    pos += 1
                chain.update(cache=cache, pos=pos, last=cur[:, 0])
                outputs[uid] = np.stack(outs, axis=1)
        return outputs
