"""The five JAX-discipline checkers (L001..L005).

Each checker is calibrated to THIS codebase's conventions (see
``docs/lint.md`` for the catalog with bad/good examples):

L001  prng-key-reuse          a tracked PRNG key variable consumed twice
                              without an intervening split/fold_in
L002  tracer-in-host-control  Python ``if``/``while``/``bool()`` on a
                              value derived from a jitted function's
                              traced parameters
L003  impure-strategy-state   ``self``/global mutation or banned host
                              APIs inside ``SearchStrategy.init/ask/tell``
                              and ``lax.scan`` bodies
L004  unlocked-shared-mutation  writes to ``# @locked:<name>`` attributes
                              outside ``with self.<name>:`` / ``@holds:``
L005  fingerprint-dtype-drift   digest inputs that depend on native byte
                              order or the Python hash seed
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.lint.core import Finding, SourceFile, checker

# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str:
    """``jax.random.split`` for the matching Attribute chain; '' when the
    expression is not a plain dotted name (calls/subscripts break it)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def iter_functions(tree: ast.AST) -> Iterator[Tuple[ast.AST, Optional[str]]]:
    """Every (sync/async) function in the module with its enclosing class
    name (None at module level; nested functions inherit the class of the
    method they are defined in)."""
    def walk(node: ast.AST, cls: Optional[str]) -> Iterator:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from walk(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child, cls
                yield from walk(child, cls)
            else:
                yield from walk(child, cls)
    yield from walk(tree, None)


def param_names(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _static_names_from_call(call: ast.Call, params: List[str]) -> Set[str]:
    """static_argnames/static_argnums keywords of a jit(...) call."""
    static: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    static.add(c.value)
        elif kw.arg == "static_argnums":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, int):
                    if 0 <= c.value < len(params):
                        static.add(params[c.value])
    return static


def jit_info(fn: ast.AST) -> Tuple[bool, Set[str]]:
    """(is jit-decorated, static parameter names).  Recognizes ``@jit``,
    ``@jax.jit``, ``@jax.jit(...)`` and ``@partial(jax.jit, ...)``."""
    params = param_names(fn)
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            name = dotted_name(dec.func)
            if name.split(".")[-1] == "partial" and dec.args:
                inner = dec.args[0]
                if dotted_name(inner).split(".")[-1] == "jit":
                    return True, _static_names_from_call(dec, params)
            elif name.split(".")[-1] == "jit":
                return True, _static_names_from_call(dec, params)
        elif dotted_name(dec).split(".")[-1] == "jit":
            return True, set()
    return False, set()


def scan_body_names(tree: ast.AST) -> Set[str]:
    """Names of functions passed (possibly via functools.partial) as the
    body argument of ``lax.scan`` / ``jax.lax.scan`` in this module."""
    bodies: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func)
        if fname not in ("lax.scan", "jax.lax.scan"):
            continue
        if not node.args:
            continue
        body = node.args[0]
        if (isinstance(body, ast.Call)
                and dotted_name(body.func).split(".")[-1] == "partial"
                and body.args):
            body = body.args[0]
        name = dotted_name(body)
        if name:
            bodies.add(name.split(".")[-1])
    return bodies


# attributes whose access yields host-static metadata, not traced values
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval"}
# calls whose result is host-static regardless of argument taint
_STATIC_CALLS = {"len", "isinstance", "type", "getattr", "hasattr", "repr",
                 "id", "callable", "range"}


def expr_tainted(node: ast.AST, tainted: Set[str]) -> bool:
    """Whether evaluating ``node`` touches a traced value: any tainted
    Name flows through, EXCEPT under shape/dtype metadata access,
    static-returning builtins, or ``is (not) None`` checks."""
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return expr_tainted(node.value, tainted)
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func)
        if fname in _STATIC_CALLS:
            return False
        parts = [expr_tainted(a, tainted) for a in node.args]
        parts += [expr_tainted(kw.value, tainted) for kw in node.keywords]
        if not isinstance(node.func, ast.Name):
            parts.append(expr_tainted(node.func, tainted))
        return any(parts)
    if isinstance(node, ast.Compare):
        # ``x is None`` patterns gate on *presence* of an optional input,
        # which is static under jit (tracers are never None)
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False
        return any(expr_tainted(c, tainted)
                   for c in [node.left] + node.comparators)
    return any(expr_tainted(c, tainted) for c in ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# L001 — prng-key-reuse
# ---------------------------------------------------------------------------

_KEY_FRESH = "fresh"
_KEY_USED = "consumed"

_KEY_PARAM_NAMES = {"key", "rng", "prng_key", "rng_key"}


def _is_key_param(name: str) -> bool:
    return name in _KEY_PARAM_NAMES or name.endswith("_key")


def _is_key_source(call: ast.Call, env: Dict[str, str]) -> bool:
    """Does this call mint fresh key material?  ``PRNGKey``/``key``/
    ``fold_in`` always; ``split`` only when it is plausibly
    ``jax.random.split`` (dotted through ``random``, or splitting a
    variable we already track) — ``"a,b".split(",")`` must not count."""
    fname = dotted_name(call.func)
    tail = fname.split(".")[-1]
    if tail in ("PRNGKey", "fold_in"):
        return True
    if tail == "key" and "random" in fname:
        return True
    if tail == "split":
        if "random" in fname:
            return True
        return any(isinstance(a, ast.Name) and a.id in env
                   for a in call.args)
    return False


@checker("L001")
def check_prng_key_reuse(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for fn, _cls in iter_functions(sf.tree):
        findings.extend(_l001_function(sf, fn))
    return findings


def _l001_function(sf: SourceFile, fn: ast.AST) -> List[Finding]:
    findings: List[Finding] = []
    seen: Set[Tuple[int, str]] = set()
    env: Dict[str, str] = {n: _KEY_FRESH for n in param_names(fn)
                           if _is_key_param(n)}

    def emit(line: int, name: str) -> None:
        if (line, name) not in seen:
            seen.add((line, name))
            findings.append(Finding(
                sf.path, line, "L001",
                f"PRNG key '{name}' consumed again without an intervening "
                f"split/fold_in"))

    def consume_uses(node: ast.AST) -> None:
        """Every tracked key passed as a call argument is a consumption;
        keys used via indexing (``keys[i]``) pick distinct sub-keys and
        are exempt."""
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            if dotted_name(sub.func) in _STATIC_CALLS:
                continue               # isinstance/len/... don't draw bits
            args = list(sub.args) + [kw.value for kw in sub.keywords]
            for a in args:
                if isinstance(a, ast.Starred):
                    a = a.value
                if isinstance(a, ast.Name) and a.id in env:
                    if env[a.id] == _KEY_USED:
                        emit(sub.lineno, a.id)
                    env[a.id] = _KEY_USED

    def bind_targets(targets: List[ast.AST], value: ast.AST) -> None:
        minted = isinstance(value, ast.Call) and _is_key_source(value, env)
        unpacks_keys = (isinstance(value, ast.Name)
                        and value.id in env) or (
            isinstance(value, ast.Subscript)
            and isinstance(value.value, ast.Name)
            and value.value.id in env)
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                for el in t.elts:
                    if isinstance(el, ast.Starred):
                        el = el.value
                    if isinstance(el, ast.Name):
                        if minted or unpacks_keys:
                            env[el.id] = _KEY_FRESH
                        else:
                            env.pop(el.id, None)
            elif isinstance(t, ast.Name):
                if minted or unpacks_keys:
                    env[t.id] = _KEY_FRESH
                else:
                    env.pop(t.id, None)

    def run_stmt(stmt: ast.AST) -> bool:
        """Process one statement; True when it terminates the block
        (return/raise/break/continue), so a branch that exits early does
        not leak its consumptions into the fall-through path."""
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return False                # nested scopes checked separately
        if isinstance(stmt, ast.Assign):
            consume_uses(stmt.value)
            bind_targets(stmt.targets, stmt.value)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            if stmt.value is not None:
                consume_uses(stmt.value)
                bind_targets([stmt.target], stmt.value)
        elif isinstance(stmt, ast.If):
            consume_uses(stmt.test)
            before = dict(env)
            body_exits = run_block(stmt.body)
            after_body = dict(env)
            env.clear()
            env.update(before)
            else_exits = run_block(stmt.orelse)
            if body_exits and not else_exits:
                pass                    # only the else path flows on
            elif else_exits and not body_exits:
                env.clear()
                env.update(after_body)
            else:                       # both flow (or both exit): merge,
                for name, st in after_body.items():   # consumed wins
                    if st == _KEY_USED or env.get(name) == _KEY_USED:
                        env[name] = _KEY_USED
                    else:
                        env.setdefault(name, st)
            return body_exits and else_exits and bool(stmt.orelse)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            consume_uses(stmt.iter)
            bind_targets([stmt.target], stmt.iter)
            run_block(stmt.body)        # twice: catches cross-iteration
            run_block(stmt.body)        # reuse without a split
            run_block(stmt.orelse)
        elif isinstance(stmt, ast.While):
            consume_uses(stmt.test)
            run_block(stmt.body)
            run_block(stmt.body)
            run_block(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                consume_uses(item.context_expr)
            return run_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            run_block(stmt.body)
            for h in stmt.handlers:
                run_block(h.body)
            run_block(stmt.orelse)
            run_block(stmt.finalbody)
        elif isinstance(stmt, (ast.Return, ast.Raise)):
            for v in ast.iter_child_nodes(stmt):
                consume_uses(v)
            return True
        elif isinstance(stmt, (ast.Break, ast.Continue)):
            return True
        elif isinstance(stmt, (ast.Expr, ast.Assert, ast.Delete)):
            for v in ast.iter_child_nodes(stmt):
                consume_uses(v)
        else:
            consume_uses(stmt)
        return False

    def run_block(stmts) -> bool:
        exits = False
        for s in stmts:
            exits = run_stmt(s) or exits
        return exits

    run_block(fn.body)
    return findings


# ---------------------------------------------------------------------------
# L002 — tracer-in-host-control-flow
# ---------------------------------------------------------------------------


@checker("L002")
def check_tracer_host_flow(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    scan_bodies = scan_body_names(sf.tree)
    for fn, _cls in iter_functions(sf.tree):
        is_jit, static = jit_info(fn)
        if not is_jit and fn.name not in scan_bodies:
            continue
        tainted = {n for n in param_names(fn)
                   if n not in static and n != "self" and n != "_"}
        _propagate_taint(fn, tainted)
        findings.extend(_l002_flag(sf, fn, tainted))
    return findings


def _propagate_taint(fn: ast.AST, tainted: Set[str]) -> None:
    """Fixpoint over simple assignments: names bound to tainted
    expressions become tainted."""
    for _ in range(8):
        grew = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets, value = [node.target], node.value
            else:
                continue
            if value is None or not expr_tainted(value, tainted):
                continue
            for t in targets:
                names = [t] if isinstance(t, ast.Name) else [
                    el for el in getattr(t, "elts", [])
                    if isinstance(el, ast.Name)]
                for n in names:
                    if n.id not in tainted:
                        tainted.add(n.id)
                        grew = True
        if not grew:
            return


def _l002_flag(sf: SourceFile, fn: ast.AST,
               tainted: Set[str]) -> List[Finding]:
    findings: List[Finding] = []

    def emit(line: int, what: str) -> None:
        findings.append(Finding(
            sf.path, line, "L002",
            f"{what} on a value traced from {fn.name}()'s parameters — "
            f"host control flow inside jit sees a Tracer, not data"))

    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            if expr_tainted(node.test, tainted):
                kind = "if" if isinstance(node, ast.If) else "while"
                emit(node.lineno, f"Python `{kind}`")
        elif isinstance(node, ast.IfExp):
            if expr_tainted(node.test, tainted):
                emit(node.lineno, "conditional expression")
        elif isinstance(node, ast.Assert):
            if expr_tainted(node.test, tainted):
                emit(node.lineno, "`assert`")
        elif isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname in ("bool", "int", "float") and node.args:
                if any(expr_tainted(a, tainted) for a in node.args):
                    emit(node.lineno, f"`{fname}()`")
    return findings


# ---------------------------------------------------------------------------
# L003 — impure-strategy-state
# ---------------------------------------------------------------------------

_STRATEGY_METHODS = {"init", "ask", "tell"}
# host APIs with no business inside a pure, jittable strategy step
_BANNED_CALL_PREFIXES = ("time.", "datetime.", "np.random.", "numpy.random.",
                        "random.")
_BANNED_CALL_NAMES = {"print", "perf_counter", "monotonic", "input", "open"}


def _strategy_classes(tree: ast.AST) -> Set[str]:
    """Classes participating in the SearchStrategy protocol, minus the
    host-loop adapters (``Host*``): their init/ask/tell must be pure
    jittable pytree transforms."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        bases = {dotted_name(b).split(".")[-1] for b in node.bases}
        if ("SearchStrategy" in bases or "Strategy" in bases) \
                and not node.name.startswith("Host"):
            out.add(node.name)
    return out


@checker("L003")
def check_impure_strategy_state(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    strategy_classes = _strategy_classes(sf.tree)
    scan_bodies = scan_body_names(sf.tree)
    for fn, cls in iter_functions(sf.tree):
        in_strategy = (cls in strategy_classes
                       and fn.name in _STRATEGY_METHODS)
        in_scan = fn.name in scan_bodies
        if not in_strategy and not in_scan:
            continue
        where = (f"{cls}.{fn.name}" if in_strategy
                 else f"scan body {fn.name}")
        tainted = {n for n in param_names(fn) if n != "self"}
        _propagate_taint(fn, tainted)
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    base = t
                    while isinstance(base, ast.Subscript):
                        base = base.value
                    if isinstance(base, ast.Attribute) \
                            and isinstance(base.value, ast.Name) \
                            and base.value.id == "self":
                        findings.append(Finding(
                            sf.path, node.lineno, "L003",
                            f"mutation of self.{base.attr} in {where} — "
                            f"strategy state must live in the pytree "
                            f"state, not on the object"))
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                findings.append(Finding(
                    sf.path, node.lineno, "L003",
                    f"{type(node).__name__.lower()} write in {where}"))
            elif isinstance(node, ast.Call):
                fname = dotted_name(node.func)
                tail = fname.split(".")[-1]
                if fname.startswith(_BANNED_CALL_PREFIXES) \
                        or fname in _BANNED_CALL_NAMES:
                    findings.append(Finding(
                        sf.path, node.lineno, "L003",
                        f"host API `{fname}()` in {where} — impure "
                        f"under jit (runs at trace time, not per step)"))
                elif tail == "__setattr__" and fname.startswith("object."):
                    findings.append(Finding(
                        sf.path, node.lineno, "L003",
                        f"object.__setattr__ in {where} — frozen-"
                        f"dataclass mutation is still mutation"))
                elif tail == "item" and not node.args and not node.keywords:
                    if expr_tainted(node.func, tainted):
                        findings.append(Finding(
                            sf.path, node.lineno, "L003",
                            f"`.item()` on a traced value in {where} — "
                            f"forces a host sync / fails under jit"))
                elif fname in ("float", "bool") and node.args:
                    if any(expr_tainted(a, tainted) for a in node.args):
                        findings.append(Finding(
                            sf.path, node.lineno, "L003",
                            f"`{fname}()` on a traced value in {where}"))
    return findings


# ---------------------------------------------------------------------------
# L004 — unlocked-shared-mutation
# ---------------------------------------------------------------------------

_MUTATOR_METHODS = {"append", "appendleft", "extend", "insert", "add",
                    "remove", "discard", "pop", "popleft", "popitem",
                    "clear", "update", "setdefault", "move_to_end",
                    "sort", "reverse"}


@checker("L004")
def check_unlocked_shared_mutation(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_l004_class(sf, node))
    return findings


def _l004_class(sf: SourceFile, cls: ast.ClassDef) -> List[Finding]:
    # locked attribute declarations inside this class's line span
    end = max((getattr(n, "end_lineno", cls.lineno) or cls.lineno
               for n in ast.walk(cls)), default=cls.lineno)
    decls: Dict[str, str] = {}
    for node in ast.walk(cls):
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        lock = sf.locked_decls.get(node.lineno)
        if lock is None and getattr(node, "end_lineno", None):
            for ln in range(node.lineno, node.end_lineno + 1):
                lock = sf.locked_decls.get(ln)
                if lock:
                    break
        if not lock:
            continue
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for t in targets:
            if isinstance(t, ast.Attribute) \
                    and isinstance(t.value, ast.Name) \
                    and t.value.id == "self":
                decls[t.attr] = lock
            elif isinstance(t, ast.Name):
                decls[t.id] = lock
    if not decls:
        return []

    findings: List[Finding] = []
    for item in cls.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if item.name == "__init__":
                continue               # construction precedes sharing
            held = set(sf.holds_for(item))
            _l004_walk(sf, item.body, decls, held, item.name, findings)
    return findings


def _l004_walk(sf: SourceFile, stmts, decls: Dict[str, str],
               held: Set[str], method: str,
               findings: List[Finding]) -> None:
    for stmt in stmts:
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            newly = set()
            for it in stmt.items:
                name = dotted_name(it.context_expr)
                if name.startswith("self."):
                    newly.add(name[len("self."):])
                elif name:
                    newly.add(name)
            _l004_walk(sf, stmt.body, decls, held | newly, method, findings)
            continue
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        _l004_check_stmt(sf, stmt, decls, held, method, findings)
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if sub:
                _l004_walk(sf, sub, decls, held, method, findings)
        for h in getattr(stmt, "handlers", []) or []:
            _l004_walk(sf, h.body, decls, held, method, findings)


def _l004_check_stmt(sf: SourceFile, stmt: ast.AST,
                     decls: Dict[str, str], held: Set[str], method: str,
                     findings: List[Finding]) -> None:
    def emit(line: int, attr: str) -> None:
        lock = decls[attr]
        findings.append(Finding(
            sf.path, line, "L004",
            f"write to self.{attr} (declared @locked:{lock}) in "
            f"{method}() outside `with self.{lock}:` — mark the method "
            f"@holds:{lock} if the caller owns the lock"))

    def locked_attr_of(t: ast.AST) -> Optional[str]:
        base = t
        while isinstance(base, ast.Subscript):
            base = base.value
        if isinstance(base, ast.Attribute) \
                and isinstance(base.value, ast.Name) \
                and base.value.id == "self" and base.attr in decls:
            return base.attr
        return None

    if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for t in targets:
            attr = locked_attr_of(t)
            if attr is not None and decls[attr] not in held:
                emit(stmt.lineno, attr)
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            attr = locked_attr_of(t)
            if attr is not None and decls[attr] not in held:
                emit(stmt.lineno, attr)
    # mutating method calls on a locked attribute — scan only this
    # statement's own expressions (compound statements recurse through
    # _l004_walk so nested `with lock:` bodies keep their held set)
    if isinstance(stmt, (ast.If, ast.While)):
        roots: List[ast.AST] = [stmt.test]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        roots = [stmt.iter]
    elif isinstance(stmt, ast.Try):
        roots = []
    else:
        roots = [stmt]
    for root in roots:
        for node in ast.walk(root):
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Attribute):
                if node.func.attr not in _MUTATOR_METHODS:
                    continue
                attr = locked_attr_of(node.func.value)
                if attr is not None and decls[attr] not in held:
                    emit(node.lineno, attr)


# ---------------------------------------------------------------------------
# L005 — fingerprint-dtype-drift
# ---------------------------------------------------------------------------


def _in_digest_scope(sf: SourceFile, fn: ast.AST) -> bool:
    norm = sf.path.replace("\\", "/")
    if norm.endswith("memo/fingerprint.py"):
        return True
    name = fn.name.lower()
    return "fingerprint" in name or "digest" in name


def _has_le_astype(node: ast.AST) -> bool:
    """Whether the value chain under ``.tobytes()`` pins an explicit
    little-endian dtype via ``.astype("<..")``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute) \
                and sub.func.attr == "astype" and sub.args:
            a = sub.args[0]
            if isinstance(a, ast.Constant) and isinstance(a.value, str) \
                    and a.value.startswith("<"):
                return True
    return False


@checker("L005")
def check_fingerprint_dtype_drift(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for fn, _cls in iter_functions(sf.tree):
        if not _in_digest_scope(sf, fn):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted_name(node.func)
            if fname == "hash":
                findings.append(Finding(
                    sf.path, node.lineno, "L005",
                    f"builtin hash() feeding {fn.name}() — salted per "
                    f"process (PYTHONHASHSEED); digest bits would change "
                    f"across runs"))
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "tobytes":
                if not _has_le_astype(node.func.value):
                    findings.append(Finding(
                        sf.path, node.lineno, "L005",
                        f".tobytes() without an explicit little-endian "
                        f".astype('<f4'/'<i4'/'<u4') in {fn.name}() — "
                        f"raw buffers drift with input dtype and native "
                        f"byte order"))
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype" and node.args:
                a = node.args[0]
                byte_order_free = (
                    isinstance(a, ast.Constant)
                    and isinstance(a.value, str)
                    and not a.value.startswith("<"))
                if byte_order_free:
                    findings.append(Finding(
                        sf.path, node.lineno, "L005",
                        f".astype({a.value!r}) in {fn.name}() leaves "
                        f"byte order native — use the '<'-prefixed "
                        f"little-endian spelling for digest inputs"))
    return findings
