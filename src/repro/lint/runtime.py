"""Runtime sanitizers — recompilation and transfer discipline, enforced.

Two invariants the static pass cannot see end-to-end:

* **No silent recompilation.**  The stream scheduler and the sweep
  engine promise that after ``warmup()`` every dispatch reuses a cached
  executable; an unexpected static-argument change (a strategy rebuilt
  non-identically, a new bucket shape, an objective spec that stopped
  hashing equal) silently recompiles mid-run and turns a
  milliseconds-scale dispatch into a seconds-scale stall.
  :class:`RecompileGuard` counts compilations and raises — naming the
  offending executables — when any happen after ``warmup()``.

* **No implicit host<->device transfers on the hot path.**  Every
  intended transfer in ``run_rows``/stream dispatch is an explicit
  ``jax.device_put``/``jax.device_get``; anything else (a numpy array
  leaking into a jitted call, a stray ``float()``) is a hidden sync.
  :func:`transfer_sanitizer` scopes ``jax.transfer_guard("disallow")``
  over a region behind a config flag (``SweepConfig.transfer_guard`` /
  ``StreamConfig.transfer_guard``).

The guard counts compilations by listening to jax's own compilation
logging (the ``Compiling <name> ...`` records ``jax``'s internal pxla
module emits at DEBUG level).  That channel names the executable —
``jax.monitoring`` compile events carry no names — and attaching a
logging handler is read-only with respect to jax internals.  The logger
name is pinned per jax version; :func:`_compile_loggers` probes the
known spellings so a jax upgrade degrades to an explicit error, not
silent non-counting.
"""
from __future__ import annotations

import contextlib
import logging
import re
import threading
from typing import Callable, List, Optional

__all__ = ["RecompileError", "RecompileGuard", "transfer_sanitizer"]


def _count_compile(label: str, post_warmup: bool) -> None:
    """Publish every observed compile to the obs registry.  Lazy import
    (``repro.obs`` imports nothing from here at module scope, but this
    module must stay importable without obs) and never raises: the
    guard runs inside a logging handler."""
    try:
        from repro.obs.registry import get_registry
        get_registry().counter(
            "repro_jit_compiles_total",
            "jit compilations observed by RecompileGuard",
        ).inc(phase="post_warmup" if post_warmup else "warmup",
              guard=label or "unlabeled")
    except Exception:
        pass


class RecompileError(RuntimeError):
    """A jit compilation happened inside a region that promised none."""


# jax 0.4.x emits "Compiling <fn> with global shapes and types ..." from
# jax._src.interpreters.pxla at DEBUG; older/newer spellings fall back
# to jax._src.dispatch.  Both may exist; listening twice is harmless
# because each compile logs "Compiling" once per module that owns it.
_COMPILE_LOGGER_NAMES = (
    "jax._src.interpreters.pxla",
    "jax._src.dispatch",
)
_COMPILE_RE = re.compile(r"^Compiling (\S+)")


class _CompileListener(logging.Handler):
    """Never raises from emit (logging would swallow it into stderr and
    the guard would silently undercount) — parse, record, move on."""

    def __init__(self, guard: "RecompileGuard"):
        super().__init__(level=logging.DEBUG)
        self._guard = guard

    def emit(self, record: logging.LogRecord) -> None:  # pragma: no cover
        try:
            m = _COMPILE_RE.match(record.getMessage())
            if m:
                self._guard._record_compile(m.group(1))
        except Exception:
            pass


class RecompileGuard:
    """Context manager asserting zero jit compilations after warmup.

        with RecompileGuard(label="stream") as guard:
            svc.warmup(trace)
            guard.warmup()          # compiles so far were expected
            svc.run(trace)          # any compile past here raises
        # __exit__ re-checks; guard.post_warmup lists offenders

    ``warmup()`` marks the boundary: everything compiled before it was
    the deliberate precompilation pass, anything after is a violation.
    Without a ``warmup()`` call the guard only observes (``compiles``
    holds every executable name) and never raises — useful for
    reporting.  Thread-safe: compilations on pool threads are counted.
    """

    def __init__(self, label: str = ""):
        self.label = label
        self.compiles: List[str] = []
        self._boundary: Optional[int] = None
        self._lock = threading.Lock()
        self._listener: Optional[_CompileListener] = None
        self._saved: List = []
        self._callbacks: List[Callable[[str, bool], None]] = []

    def add_listener(self, fn: Callable[[str, bool], None]) -> None:
        """Register ``fn(executable_name, post_warmup)`` to run on every
        recorded compile (the obs flight recorder hooks in here)."""
        with self._lock:
            self._callbacks.append(fn)

    # -- listener plumbing ----------------------------------------------------
    def _record_compile(self, name: str) -> None:
        with self._lock:
            self.compiles.append(name)
            post = self._boundary is not None
            callbacks = list(self._callbacks)
        _count_compile(self.label, post)
        for fn in callbacks:
            try:
                fn(name, post)
            except Exception:       # never raise from the log handler
                pass

    def __enter__(self) -> "RecompileGuard":
        self._listener = _CompileListener(self)
        for lname in _COMPILE_LOGGER_NAMES:
            lg = logging.getLogger(lname)
            opened = not lg.isEnabledFor(logging.DEBUG)
            self._saved.append((lg, lg.level, lg.propagate, opened))
            if opened:
                # WE opened the level just to hear the compile records:
                # stop propagation so they reach only our handler and
                # never hit the user's (or jax's own) stderr handlers.
                # A logger already at DEBUG keeps propagating — the user
                # asked for those logs and the guard must not eat them.
                lg.setLevel(logging.DEBUG)
                lg.propagate = False
            lg.addHandler(self._listener)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        for lg, level, propagate, opened in self._saved:
            lg.removeHandler(self._listener)
            if opened:
                lg.setLevel(level)
                lg.propagate = propagate
        self._saved.clear()
        self._listener = None
        if exc_type is None:
            self.check()
        return False

    # -- the contract ---------------------------------------------------------
    def warmup(self) -> "RecompileGuard":
        """Mark the boundary: compilations so far were the warmup."""
        with self._lock:
            self._boundary = len(self.compiles)
        return self

    @property
    def warmup_compiles(self) -> List[str]:
        with self._lock:
            cut = (len(self.compiles) if self._boundary is None
                   else self._boundary)
            return list(self.compiles[:cut])

    @property
    def post_warmup(self) -> List[str]:
        """Executables compiled after ``warmup()`` (the violations)."""
        with self._lock:
            if self._boundary is None:
                return []
            return list(self.compiles[self._boundary:])

    def check(self) -> None:
        """Raise :class:`RecompileError` naming every executable
        compiled after ``warmup()`` (no-op before ``warmup()``)."""
        bad = self.post_warmup
        if bad:
            label = f" [{self.label}]" if self.label else ""
            names = ", ".join(sorted(set(bad)))
            raise RecompileError(
                f"{len(bad)} jit compilation(s) after warmup{label}: "
                f"{names} — a static argument changed (strategy/objective "
                f"not hashing equal, or an unwarmed bucket shape)")


@contextlib.contextmanager
def transfer_sanitizer(enabled: bool = True):
    """Scoped ``jax.transfer_guard("disallow")`` (no-op when disabled).

    Inside the scope every implicit host<->device transfer raises;
    ``jax.device_put`` / ``jax.device_get`` / ``jnp.asarray`` are
    explicit and stay allowed — which is exactly the discipline the hot
    paths follow.  Intentional implicit transfers inside the scope (none
    on the hot paths today) would wrap themselves in
    ``jax.transfer_guard("allow")``.
    """
    if not enabled:
        yield
        return
    import jax
    with jax.transfer_guard("disallow"):
        yield
