"""Concurrency harness — hammer the shared-state hot spots, assert exact.

Run: ``python -m repro.lint.race [--ops-per-owner N] [--threads T]``

Two phases, both with *deterministic* expected states so every assertion
is bit-exact (no "roughly consistent" checks that let lost updates hide):

* **MemoStore ownership race.**  T threads plus one real subprocess each
  own a disjoint slice of fingerprints and replay a deterministic
  put/discard script against ONE shared on-disk store, with periodic
  ``refresh()``/``compact()`` thrown in (and auto-compaction firing on
  its own).  Because ids are disjoint and replay is last-wins, the final
  index must agree exactly with each owner's script replayed serially:
  a lost ``put`` line, a lost ``del`` tombstone (the compaction-window
  bug), or a corrupted index all break the equality.  Verified three
  ways: a pure-JSON serial replay of ``index.jsonl``, a fresh
  :class:`~repro.memo.store.MemoStore` load, and payload bytes against
  regenerated arrays.

* **AnalysisPool determinism race.**  The same scenario requests
  analyzed concurrently (shared per-setting ``JobAnalyzer`` caches,
  profile-cache contention) and serially must produce bit-identical
  fitness tables.

A separate single-process eviction phase exercises the LRU byte budget
(evictions append tombstones, so they would violate the ownership
invariant if run concurrently — by design the race phase runs without a
budget).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

PAYLOAD_N = 6          # floats per record: tiny, the index is the story
RECS_PER_OWNER = 8     # live fingerprints each owner cycles through


# ---------------------------------------------------------------------------
# deterministic ownership scripts
# ---------------------------------------------------------------------------
def owner_ops(worker: int, n_ops: int,
              n_recs: int = RECS_PER_OWNER) -> List[Tuple[str, str, int]]:
    """The op script for one owner: ``(op, fingerprint, version)``.

    Pure function of ``(worker, n_ops)`` so the verifier can replay it.
    Every 5th op is a discard; versions strictly increase so last-wins
    replay has a unique right answer per fingerprint.
    """
    ops = []
    for j in range(n_ops):
        r = (j * 7 + worker) % n_recs
        fp = f"w{worker}r{r}"
        if j % 5 == 4:
            ops.append(("del", fp, j))
        else:
            ops.append(("put", fp, j))
    return ops


def payload(worker: int, fp: str, version: int) -> Dict[str, np.ndarray]:
    """Bit-reproducible arrays keyed by (owner, fingerprint, version)."""
    r = int(fp.rsplit("r", 1)[1])
    x = (np.arange(PAYLOAD_N, dtype=np.float32) * (version + 1)
         + worker * 1000 + r * 10)
    return {"x": x}


def expected_state(worker: int, n_ops: int,
                   n_recs: int = RECS_PER_OWNER) -> Dict[str, int]:
    """Serial replay of one owner's script: fingerprint -> final version."""
    state: Dict[str, int] = {}
    for op, fp, ver in owner_ops(worker, n_ops, n_recs):
        if op == "put":
            state[fp] = ver
        else:
            state.pop(fp, None)
    return state


def run_owner(path: str, worker: int, n_ops: int,
              n_recs: int = RECS_PER_OWNER) -> None:
    """Replay one owner's script against the shared store (worker body
    for both the thread owners and the subprocess owner)."""
    from repro.memo.store import MemoRecord, MemoStore
    store = MemoStore(path)
    for j, (op, fp, ver) in enumerate(owner_ops(worker, n_ops, n_recs)):
        if op == "put":
            store.put(MemoRecord(fingerprint=fp, family=(f"fam{worker}",),
                                 arrays=payload(worker, fp, ver),
                                 meta={"v": ver, "w": worker}))
        else:
            store.discard(fp)
        # cross-process visibility + compaction churn, mid-script
        if j % 67 == 66:
            store.refresh()
        if j % 151 == 150:
            store.compact()


# ---------------------------------------------------------------------------
# verification
# ---------------------------------------------------------------------------
def replay_index(path: str) -> Dict[str, Dict]:
    """Pure-JSON last-wins replay of index.jsonl: fp -> final put event.

    Independent of MemoStore's loader, so loader bugs and index bugs
    can't cancel each other out.
    """
    live: Dict[str, Dict] = {}
    with open(os.path.join(path, "index.jsonl")) as f:
        for raw in f:
            raw = raw.strip()
            if not raw:
                continue
            ev = json.loads(raw)     # a torn line here IS a finding
            if ev["op"] == "put":
                live[ev["fp"]] = ev
            elif ev["op"] == "del":
                live.pop(ev["fp"], None)
    return live


def verify_store(path: str, n_owners: int, n_ops: int,
                 n_recs: int = RECS_PER_OWNER) -> List[str]:
    """Every ownership invariant; returns human-readable violations."""
    from repro.memo.store import MemoStore
    errors: List[str] = []
    want: Dict[str, Tuple[int, int]] = {}        # fp -> (worker, version)
    for w in range(n_owners):
        for fp, ver in expected_state(w, n_ops, n_recs).items():
            want[fp] = (w, ver)

    idx = replay_index(path)
    if set(idx) != set(want):
        lost = sorted(set(want) - set(idx))
        ghost = sorted(set(idx) - set(want))
        if lost:
            errors.append(f"index lost puts: {lost}")
        if ghost:
            errors.append(f"index resurrected tombstoned records: {ghost}")
    for fp in set(idx) & set(want):
        w, ver = want[fp]
        got = idx[fp].get("meta", {}).get("v")
        if got != ver:
            errors.append(f"index {fp}: version {got}, want {ver} "
                          "(stale line won the replay)")

    fresh = MemoStore(path)
    with fresh._lock:
        loaded = {fp: rec for fp, rec in fresh._records.items()}
    if set(loaded) != set(idx):
        errors.append("loader/index divergence: "
                      f"{sorted(set(loaded) ^ set(idx))}")
    for fp, rec in loaded.items():
        if fp not in want:
            continue
        w, ver = want[fp]
        ref = payload(w, fp, ver)["x"]
        got = rec.arrays.get("x")
        if got is None or got.dtype != ref.dtype \
                or not np.array_equal(got, ref):
            errors.append(f"payload {fp}: bytes differ from serial replay")
        if rec.meta.get("v") != ver:
            errors.append(f"loaded {fp}: meta version {rec.meta.get('v')}, "
                          f"want {ver}")
    return errors


# ---------------------------------------------------------------------------
# phases
# ---------------------------------------------------------------------------
def memo_race(path: str, threads: int = 3, ops_per_owner: int = 250,
              use_subprocess: bool = True) -> int:
    """Interleave the owners; raise AssertionError on any violation.
    Returns total ops executed."""
    n_owners = threads + (1 if use_subprocess else 0)
    errs: List[BaseException] = []

    def body(w):
        try:
            run_owner(path, w, ops_per_owner)
        except BaseException as e:       # surfaced below, never swallowed
            errs.append(e)

    ts = [threading.Thread(target=body, args=(w,), name=f"owner-{w}")
          for w in range(threads)]
    proc = None
    if use_subprocess:
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.lint.race", "--owner",
             str(threads), "--dir", path, "--ops-per-owner",
             str(ops_per_owner)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    if proc is not None:
        out, err = proc.communicate(timeout=600)
        if proc.returncode != 0:
            raise AssertionError(
                f"subprocess owner failed (rc={proc.returncode}):\n"
                f"{err.decode(errors='replace')}")
    if errs:
        raise errs[0]
    violations = verify_store(path, n_owners, ops_per_owner)
    if violations:
        raise AssertionError("memo race violations:\n  "
                             + "\n  ".join(violations))
    return n_owners * ops_per_owner


def eviction_phase(path: str, budget_records: int = 4) -> None:
    """Single-process LRU budget stress: the survivor set and byte count
    must match the deterministic LRU prediction."""
    from repro.memo.store import MemoRecord, MemoStore
    one = payload(0, "w0r0", 0)["x"].nbytes
    store = MemoStore(path, byte_budget=budget_records * one)
    n = 12
    for ver in range(n):
        fp = f"ev{ver}"
        store.put(MemoRecord(fingerprint=fp, family=("ev",),
                             arrays=payload(0, f"w0r{ver % 8}", ver),
                             meta={"v": ver}))
    assert store.total_bytes <= budget_records * one
    assert sorted(store._records) == sorted(
        f"ev{v}" for v in range(n - budget_records, n)), \
        f"LRU survivors wrong: {sorted(store._records)}"
    fresh = MemoStore(path)
    assert sorted(fresh._records) == sorted(store._records), \
        "eviction tombstones did not persist"


def analysis_race(threads: int = 4, n_jobs: int = 10) -> int:
    """Concurrent AnalysisPool results must be bit-identical to serial."""
    import jax
    from repro.stream.analysis import AnalysisPool, analyze_serial
    from repro.stream.workloads import TraceConfig, generate_trace
    reqs = generate_trace(TraceConfig(
        num_scenarios=n_jobs, group_size=10, settings=("S2", "S3"),
        bw_ladder_gb=(1.0, 16.0), seed=7))
    with AnalysisPool(workers=threads) as pool:
        futs = [pool.submit(r) for r in reqs]
        conc = [f.result() for f in futs]
    serial = analyze_serial(reqs)
    for c, s in zip(conc, serial):
        assert c.request.uid == s.request.uid
        cl = jax.tree.leaves(c.fit.params)
        sl = jax.tree.leaves(s.fit.params)
        assert len(cl) == len(sl)
        for a, b in zip(cl, sl):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    return len(reqs)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint.race",
        description="Concurrency harness: MemoStore ownership race, "
                    "LRU eviction, AnalysisPool determinism.")
    ap.add_argument("--dir", default=None,
                    help="store directory (default: a fresh tempdir)")
    ap.add_argument("--threads", type=int, default=3,
                    help="thread owners (one subprocess owner is added)")
    ap.add_argument("--ops-per-owner", type=int, default=250)
    ap.add_argument("--no-subprocess", action="store_true")
    ap.add_argument("--skip-analysis", action="store_true",
                    help="memo phases only (no jax import)")
    ap.add_argument("--owner", type=int, default=None,
                    help=argparse.SUPPRESS)     # subprocess entry
    args = ap.parse_args(argv)

    if args.owner is not None:                  # child mode
        run_owner(args.dir, args.owner, args.ops_per_owner)
        return 0

    import tempfile
    path = args.dir or tempfile.mkdtemp(prefix="repro-race-")
    total = memo_race(path, threads=args.threads,
                      ops_per_owner=args.ops_per_owner,
                      use_subprocess=not args.no_subprocess)
    print(f"memo race: {total} interleaved ops over "
          f"{args.threads + (0 if args.no_subprocess else 1)} owners "
          f"({'threads only' if args.no_subprocess else 'threads + 1 process'})"
          f" — index exact vs serial replay")
    eviction_phase(tempfile.mkdtemp(prefix="repro-race-ev-"))
    print("eviction: LRU survivor set exact, tombstones persisted")
    if not args.skip_analysis:
        n = analysis_race()
        print(f"analysis pool: {n} concurrent analyses bit-equal serial")
    print("repro.lint.race: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
