"""repro.lint — JAX-discipline static analyzer + runtime sanitizers.

Static side (``python -m repro.lint src/ [--strict]``): five AST
checkers tuned to this codebase's invariants — single-use PRNG keys,
no host control flow on tracers, pure strategy state, lock-guarded
shared mutation, byte-stable fingerprint inputs.  See ``docs/lint.md``.

Runtime side (``repro.lint.runtime``): ``RecompileGuard`` (fails a run
that recompiles after ``warmup()``), ``transfer_sanitizer`` (scoped
``jax.transfer_guard("disallow")``), and ``repro.lint.race`` (the
MemoStore/AnalysisPool concurrency harness).
"""
from repro.lint import checkers as _checkers  # registers L001..L005
from repro.lint.core import (CHECKERS, RULES, Finding, SourceFile,
                             lint_file, lint_text, run)

del _checkers

__all__ = ["CHECKERS", "RULES", "Finding", "SourceFile", "lint_file",
           "lint_text", "run"]
