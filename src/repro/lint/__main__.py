"""CLI: ``python -m repro.lint [paths...] [--strict] [--select L001,..]``.

Report mode (default) prints findings and exits 0 — the feedback loop
for tests/ and work in progress.  ``--strict`` exits 1 on any
unsuppressed finding — the CI gate for src/.
"""
from __future__ import annotations

import argparse
import sys

from repro.lint.core import RULES, run


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX-discipline static analyzer (L001..L005)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unsuppressed finding")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule IDs to run (default: all)")
    args = ap.parse_args(argv)

    select = None
    if args.select:
        select = {r.strip() for r in args.select.split(",") if r.strip()}
        unknown = select - set(RULES)
        if unknown:
            ap.error(f"unknown rule(s): {', '.join(sorted(unknown))}; "
                     f"known: {', '.join(sorted(RULES))}")

    findings = run(args.paths, select=select)
    for f in findings:
        print(f.render())
    n = len(findings)
    mode = "strict" if args.strict else "report-only"
    print(f"repro.lint: {n} finding{'s' if n != 1 else ''} ({mode})")
    return 1 if (args.strict and findings) else 0


if __name__ == "__main__":
    sys.exit(main())
