"""Lint engine — source model, pragma grammar, checker registry, runner.

The analyzer is a plain-``ast`` pass (no imports of the checked code, no
jax): each checker receives a :class:`SourceFile` (parsed tree + raw
lines + the pragma/annotation side-channel) and returns
:class:`Finding`s.  Everything codebase-specific lives in
``repro.lint.checkers``; this module is the machinery.

Pragma grammar (all parsed from raw comment text, so they work on any
line the tokenizer keeps):

``# lint: disable=LXXX(reason)``
    Suppress rule LXXX on this line (or, when the pragma comment stands
    alone on a line, on the next line).  The parenthesized reason is
    MANDATORY — a suppression nobody can explain is a bug with a
    blindfold — and several rules may be listed comma-separated.  A
    pragma that does not parse is itself a finding (L000), and L000
    cannot be suppressed.

``# @locked:<lockname>``
    Declares that the attribute(s) assigned on this line are guarded by
    ``self.<lockname>``: every write to them outside a ``with
    self.<lockname>:`` block (or a ``@holds:``-marked method) is an L004
    finding.  Put it on the ``__init__`` assignment that creates the
    attribute.

``@holds:<lockname>``
    In a function's docstring or on its ``def`` line: the function is
    only ever called with ``<lockname>`` already held (non-lexical lock
    ownership — e.g. ``MemoStore._insert`` runs under the ``put()``
    lock).  L004 trusts the marker; the call-graph discipline it asserts
    is reviewed by humans, which is exactly why it must be spelled out.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Callable, Dict, List, Optional, Sequence, Set

RULES: Dict[str, str] = {
    "L000": "malformed-pragma",
    "L001": "prng-key-reuse",
    "L002": "tracer-in-host-control-flow",
    "L003": "impure-strategy-state",
    "L004": "unlocked-shared-mutation",
    "L005": "fingerprint-dtype-drift",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        slug = RULES.get(self.rule, "?")
        return f"{self.path}:{self.line}: {self.rule} [{slug}] {self.message}"


_PRAGMA_RE = re.compile(r"#\s*lint:\s*disable=(.*)$")
_PRAGMA_ITEM_RE = re.compile(r"^(L\d{3})\(([^()]*)\)$")
_PRAGMA_SCAN_RE = re.compile(r"L\d{3}\([^()]*\)")
_LOCKED_RE = re.compile(r"#.*@locked:([A-Za-z_]\w*)")
_HOLDS_RE = re.compile(r"@holds:([A-Za-z_]\w*)")


class SourceFile:
    """One parsed module plus its comment side-channel (pragmas, lock
    annotations).  Checkers never re-read the file."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self.pragma_findings: List[Finding] = []
        # line -> rules disabled there
        self.disabled: Dict[int, Set[str]] = {}
        # line -> lockname declared by a  # @locked:<name>  comment
        self.locked_decls: Dict[int, str] = {}
        self._parse_comments()

    # -- comment side-channel -------------------------------------------------
    def _parse_comments(self) -> None:
        # real COMMENT tokens only: a docstring QUOTING the pragma
        # grammar (like this module's) must not register as a pragma
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(self.text).readline))
        except tokenize.TokenError:
            return
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            i = tok.start[0]
            m = _LOCKED_RE.search(tok.string)
            if m:
                self.locked_decls[i] = m.group(1)
            m = _PRAGMA_RE.search(tok.string)
            if m:
                self._parse_pragma(i, m.group(1).strip())

    def _parse_pragma(self, line: int, body: str) -> None:
        items = _PRAGMA_SCAN_RE.findall(body)
        residue = _PRAGMA_SCAN_RE.sub("", body).replace(",", "").strip()
        rules: Set[str] = set()
        ok = bool(items) and not residue
        for item in items:
            m = _PRAGMA_ITEM_RE.match(item)
            if m is None or not m.group(2).strip():
                ok = False
                continue
            rules.add(m.group(1))
        if not ok:
            self.pragma_findings.append(Finding(
                self.path, line, "L000",
                f"malformed pragma {body!r}: expected "
                f"'# lint: disable=LXXX(reason)' with a non-empty reason"))
            return
        self.disabled.setdefault(line, set()).update(rules)

    def is_disabled(self, rule: str, line: int) -> bool:
        """A finding is suppressed by a pragma on its own line, or on an
        immediately preceding comment-only line."""
        if rule in self.disabled.get(line, ()):
            return True
        prev = line - 1
        if (rule in self.disabled.get(prev, ())
                and 1 <= prev <= len(self.lines)
                and self.lines[prev - 1].lstrip().startswith("#")):
            return True
        return False

    def holds_for(self, fn: ast.AST) -> Set[str]:
        """Locknames a function declares it is called holding
        (``@holds:<name>`` on the def line(s) or in the docstring)."""
        held: Set[str] = set()
        doc = ast.get_docstring(fn, clean=False)
        if doc:
            held.update(_HOLDS_RE.findall(doc))
        body_start = fn.body[0].lineno if fn.body else fn.lineno + 1
        for i in range(fn.lineno, min(body_start, len(self.lines)) + 1):
            if 1 <= i <= len(self.lines):
                held.update(_HOLDS_RE.findall(self.lines[i - 1]))
        return held


CheckerFn = Callable[[SourceFile], List[Finding]]
CHECKERS: Dict[str, CheckerFn] = {}


def checker(rule: str) -> Callable[[CheckerFn], CheckerFn]:
    """Register ``fn`` as the implementation of ``rule``."""
    if rule not in RULES:
        raise ValueError(f"unknown rule {rule!r}; add it to RULES first")

    def deco(fn: CheckerFn) -> CheckerFn:
        CHECKERS[rule] = fn
        return fn
    return deco


def lint_text(path: str, text: str,
              select: Optional[Set[str]] = None) -> List[Finding]:
    """Lint one module's source; returns unsuppressed findings sorted by
    (line, rule).  Syntax errors surface as a single E999 finding."""
    try:
        sf = SourceFile(path, text)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "E999",
                        f"syntax error: {e.msg}")]
    findings = list(sf.pragma_findings)
    for rule in sorted(CHECKERS):
        if select and rule not in select:
            continue
        findings.extend(CHECKERS[rule](sf))
    kept = []
    for f in findings:
        if f.rule != "L000" and sf.is_disabled(f.rule, f.line):
            continue
        if select and f.rule not in select and f.rule != "L000":
            continue
        kept.append(f)
    return sorted(kept, key=lambda f: (f.line, f.rule, f.message))


def lint_file(path: str, select: Optional[Set[str]] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        return lint_text(path, f.read(), select=select)


def iter_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
        elif p.endswith(".py"):
            out.append(p)
    return out


def run(paths: Sequence[str],
        select: Optional[Set[str]] = None) -> List[Finding]:
    """Lint every ``.py`` under ``paths``; returns all unsuppressed
    findings."""
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(lint_file(path, select=select))
    return findings
