"""The paper's DNN model zoo as layer-descriptor lists.

Section VI-A1: Vision (MobileNetV2, ResNet50, ShuffleNet, VGG16, MNASNet,
SqueezeNet, ...), Language (GPT-2, MobileBERT, TransformerXL, BERT, ...),
Recommendation (DLRM, Wide&Deep, NCF, DIN, ...).

Each model is a coarse list of its *distinct* layer shapes with repeat
counts — a job is a mini-batch of one layer, so only the layer's loop dims
matter.  Embedding lookups are kept on the host (Section II-A) and never
become jobs.  Mini-batch sizes follow the paper's batched-job framing:
vision N=16 images, language seq=128 tokens, recommendation batch=8
(calibrated so the per-job latency/BW orderings match the paper's Fig. 7:
vision highest latency / lowest BW, recommendation the reverse).
"""
from __future__ import annotations

from typing import Dict, List

from repro.costmodel.layers import LayerDesc, attention_fcs, conv2d, dwconv2d, fc

VISION_N = 16
LANG_SEQ = 128
RECOM_B = 8


def _repeat(layers: List[LayerDesc], times: int) -> List[LayerDesc]:
    return [l for _ in range(times) for l in layers]


# --------------------------------------------------------------------------
# Vision
# --------------------------------------------------------------------------
def resnet50() -> List[LayerDesc]:
    N = VISION_N
    ls: List[LayerDesc] = [conv2d("stem", N, 64, 3, 112, 112, 7, 7, 2)]
    # (out, mid, spatial, blocks)
    for i, (K, mid, Y, blocks) in enumerate(
            [(256, 64, 56, 3), (512, 128, 28, 4),
             (1024, 256, 14, 6), (2048, 512, 7, 3)]):
        block = [
            conv2d(f"s{i}.c1", N, mid, K, Y, Y, 1, 1),
            conv2d(f"s{i}.c2", N, mid, mid, Y, Y, 3, 3),
            conv2d(f"s{i}.c3", N, K, mid, Y, Y, 1, 1),
        ]
        ls += _repeat(block, blocks)
    ls.append(fc("head", N, 1000, 2048))
    return ls


def mobilenetv2() -> List[LayerDesc]:
    N = VISION_N
    ls: List[LayerDesc] = [conv2d("stem", N, 32, 3, 112, 112, 3, 3, 2)]
    # (in, out, expand, spatial, blocks)
    cfg = [(32, 16, 1, 112, 1), (16, 24, 6, 56, 2), (24, 32, 6, 28, 3),
           (32, 64, 6, 14, 4), (64, 96, 6, 14, 3), (96, 160, 6, 7, 3),
           (160, 320, 6, 7, 1)]
    for i, (cin, cout, e, Y, blocks) in enumerate(cfg):
        block = [
            conv2d(f"b{i}.expand", N, cin * e, cin, Y, Y, 1, 1),
            dwconv2d(f"b{i}.dw", N, cin * e, Y, Y, 3, 3),
            conv2d(f"b{i}.project", N, cout, cin * e, Y, Y, 1, 1),
        ]
        ls += _repeat(block, blocks)
    ls += [conv2d("head_conv", N, 1280, 320, 7, 7, 1, 1),
           fc("head", N, 1000, 1280)]
    return ls


def shufflenet() -> List[LayerDesc]:
    N = VISION_N
    ls: List[LayerDesc] = [conv2d("stem", N, 24, 3, 56, 56, 3, 3, 2)]
    for i, (C, Y, blocks) in enumerate([(116, 28, 4), (232, 14, 8), (464, 7, 4)]):
        block = [
            conv2d(f"s{i}.pw1", N, C // 2, C // 2, Y, Y, 1, 1),
            dwconv2d(f"s{i}.dw", N, C // 2, Y, Y, 3, 3),
            conv2d(f"s{i}.pw2", N, C // 2, C // 2, Y, Y, 1, 1),
        ]
        ls += _repeat(block, blocks)
    ls += [conv2d("head_conv", N, 1024, 464, 7, 7, 1, 1),
           fc("head", N, 1000, 1024)]
    return ls


def vgg16() -> List[LayerDesc]:
    N = VISION_N
    ls: List[LayerDesc] = []
    for i, (C, K, Y, blocks) in enumerate(
            [(3, 64, 224, 1), (64, 64, 224, 1), (64, 128, 112, 2),
             (128, 256, 56, 3), (256, 512, 28, 3), (512, 512, 14, 3)]):
        ls += _repeat([conv2d(f"c{i}", N, K, max(C, K // 2), Y, Y, 3, 3)], blocks)
    ls += [fc("fc1", N, 4096, 25088), fc("fc2", N, 4096, 4096),
           fc("fc3", N, 1000, 4096)]
    return ls


def mnasnet() -> List[LayerDesc]:
    N = VISION_N
    ls: List[LayerDesc] = [conv2d("stem", N, 32, 3, 112, 112, 3, 3, 2)]
    cfg = [(32, 24, 3, 56, 3), (24, 40, 3, 28, 3), (40, 80, 6, 14, 3),
           (80, 112, 6, 14, 2), (112, 160, 6, 7, 3)]
    for i, (cin, cout, e, Y, blocks) in enumerate(cfg):
        block = [
            conv2d(f"b{i}.expand", N, cin * e, cin, Y, Y, 1, 1),
            dwconv2d(f"b{i}.dw", N, cin * e, Y, Y, 5 if i % 2 else 3, 5 if i % 2 else 3),
            conv2d(f"b{i}.project", N, cout, cin * e, Y, Y, 1, 1),
        ]
        ls += _repeat(block, blocks)
    ls.append(fc("head", N, 1000, 1280))
    return ls


# --------------------------------------------------------------------------
# Language (attention/MLP as FC bags; Section II-A)
# --------------------------------------------------------------------------
def gpt2() -> List[LayerDesc]:
    ls: List[LayerDesc] = []
    for i in range(12):
        ls += attention_fcs(f"L{i}", LANG_SEQ, 768, 12, d_ff=3072)
    return ls


def mobilebert() -> List[LayerDesc]:
    ls: List[LayerDesc] = []
    for i in range(24):
        # bottlenecked blocks: tiny 128-dim attention + stacked 512 FFNs
        ls += attention_fcs(f"L{i}", LANG_SEQ, 128, 4, d_ff=512)
        ls += [fc(f"L{i}.ffn2_in", LANG_SEQ, 512, 128),
               fc(f"L{i}.ffn2_out", LANG_SEQ, 128, 512)]
    return ls


def transformerxl() -> List[LayerDesc]:
    ls: List[LayerDesc] = []
    for i in range(16):
        # memory-augmented attention: keys/values over 2x segment length
        ls += attention_fcs(f"L{i}", LANG_SEQ, 512, 8, d_ff=2048)
        ls.append(fc(f"L{i}.mem_scores", LANG_SEQ * 8, LANG_SEQ, 64))
    return ls


def bert_base() -> List[LayerDesc]:
    ls: List[LayerDesc] = []
    for i in range(12):
        ls += attention_fcs(f"L{i}", LANG_SEQ, 768, 12, d_ff=3072)
    return ls


# --------------------------------------------------------------------------
# Streaming heavy/light mixes (HERALD / MAGMA multi-DNN serving workloads:
# AlphaGoZero, DeepSpeech2, FasterRCNN, Transformer join NCF + ResNet50)
# --------------------------------------------------------------------------
def alphagozero() -> List[LayerDesc]:
    """20-block residual tower: 256-channel 3x3 convs on the 19x19 board.
    Compute-heavy, tiny activations — the canonical 'heavy' job source."""
    N = VISION_N
    ls: List[LayerDesc] = [conv2d("stem", N, 256, 17, 19, 19, 3, 3)]
    for i in range(20):
        ls += [conv2d(f"b{i}.c1", N, 256, 256, 19, 19, 3, 3),
               conv2d(f"b{i}.c2", N, 256, 256, 19, 19, 3, 3)]
    ls += [conv2d("policy_conv", N, 2, 256, 19, 19, 1, 1),
           fc("policy_fc", N, 362, 2 * 19 * 19),
           conv2d("value_conv", N, 1, 256, 19, 19, 1, 1),
           fc("value_fc1", N, 256, 19 * 19), fc("value_fc2", N, 1, 256)]
    return ls


def deepspeech2() -> List[LayerDesc]:
    """2D conv frontend + bidirectional GRU stack (GRUs as FC bags over the
    time axis, Section II-A style) + CTC head.  BW-hungry, light compute."""
    T = LANG_SEQ                       # spectrogram frames after striding
    ls: List[LayerDesc] = [
        conv2d("conv1", 1, 32, 1, T, 41, 11, 41, 2),
        conv2d("conv2", 1, 32, 32, T, 21, 11, 21, 1),
    ]
    d_in, d_h = 32 * 21, 800
    for i in range(5):
        # one bidirectional GRU layer = 2 directions x (input + recurrent)
        # gate GEMMs, each producing 3 gates of width d_h
        for dr in ("fw", "bw"):
            ls += [fc(f"gru{i}.{dr}.x", T, 3 * d_h, d_in if i == 0 else 2 * d_h),
                   fc(f"gru{i}.{dr}.h", T, 3 * d_h, d_h)]
    ls.append(fc("ctc_head", T, 29, 2 * d_h))
    return ls


def fasterrcnn() -> List[LayerDesc]:
    """ResNet50 backbone + RPN + RoI detection head (paper's FasterRCNN)."""
    N = VISION_N
    ls = resnet50()[:-1]               # backbone sans the classifier head
    ls += [conv2d("rpn.conv", N, 512, 2048, 14, 14, 3, 3),
           conv2d("rpn.cls", N, 18, 512, 14, 14, 1, 1),
           conv2d("rpn.box", N, 36, 512, 14, 14, 1, 1),
           # RoI head over 128 proposals of 7x7x256 pooled features
           fc("roi.fc1", 128, 1024, 7 * 7 * 256),
           fc("roi.fc2", 128, 1024, 1024),
           fc("roi.cls", 128, 91, 1024), fc("roi.box", 128, 364, 1024)]
    return ls


def transformer() -> List[LayerDesc]:
    """Transformer-base (6 encoder + 6 decoder layers, d=512, h=8)."""
    ls: List[LayerDesc] = []
    for i in range(6):
        ls += attention_fcs(f"enc{i}", LANG_SEQ, 512, 8, d_ff=2048)
    for i in range(6):
        # decoder: self-attention + cross-attention + FFN (two FC bags)
        ls += attention_fcs(f"dec{i}.self", LANG_SEQ, 512, 8, d_ff=2048)
        ls += attention_fcs(f"dec{i}.cross", LANG_SEQ, 512, 8)
    return ls


# --------------------------------------------------------------------------
# Recommendation (MLPs over large batches; embeddings stay on host)
# --------------------------------------------------------------------------
def dlrm() -> List[LayerDesc]:
    B = RECOM_B
    return [fc("bot1", B, 512, 13), fc("bot2", B, 256, 512),
            fc("bot3", B, 64, 256),
            fc("top1", B, 512, 512), fc("top2", B, 256, 512),
            fc("top3", B, 1, 256)]


def widedeep() -> List[LayerDesc]:
    B = RECOM_B
    return [fc("deep1", B, 1024, 512), fc("deep2", B, 512, 1024),
            fc("deep3", B, 256, 512), fc("wide", B, 1, 1024),
            fc("head", B, 1, 256)]


def ncf() -> List[LayerDesc]:
    B = RECOM_B
    return [fc("mlp1", B, 256, 128), fc("mlp2", B, 128, 256),
            fc("mlp3", B, 64, 128), fc("gmf", B, 64, 64),
            fc("head", B, 1, 128)]


def din() -> List[LayerDesc]:
    B = RECOM_B
    return [fc("attn1", B, 80, 144), fc("attn2", B, 40, 80),
            fc("attn3", B, 1, 40),
            fc("mlp1", B, 200, 288), fc("mlp2", B, 80, 200),
            fc("head", B, 2, 80)]


MODEL_ZOO = {
    # vision
    "resnet50": resnet50, "mobilenetv2": mobilenetv2, "shufflenet": shufflenet,
    "vgg16": vgg16, "mnasnet": mnasnet,
    # language
    "gpt2": gpt2, "mobilebert": mobilebert, "transformerxl": transformerxl,
    "bert_base": bert_base,
    # streaming heavy/light workloads
    "alphagozero": alphagozero, "deepspeech2": deepspeech2,
    "fasterrcnn": fasterrcnn, "transformer": transformer,
    # recommendation
    "dlrm": dlrm, "widedeep": widedeep, "ncf": ncf, "din": din,
}

TASK_MODELS = {
    "Vision": ["resnet50", "mobilenetv2", "shufflenet", "vgg16", "mnasnet"],
    "Lang": ["gpt2", "mobilebert", "transformerxl", "bert_base"],
    "Recom": ["dlrm", "widedeep", "ncf", "din"],
    "Mix": ["resnet50", "mobilenetv2", "shufflenet",
            "gpt2", "mobilebert", "transformerxl",
            "dlrm", "widedeep", "ncf"],
    # streaming arrival mixes (repro.stream): the HERALD/MAGMA serving
    # lineup split into compute-heavy and BW-light job sources
    "Heavy": ["alphagozero", "fasterrcnn", "resnet50"],
    "Light": ["deepspeech2", "ncf", "transformer"],
    "HeavyLight": ["alphagozero", "fasterrcnn", "resnet50",
                   "deepspeech2", "ncf", "transformer"],
}


def model_layers(name: str) -> List[LayerDesc]:
    return MODEL_ZOO[name]()
