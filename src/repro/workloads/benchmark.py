"""Task benchmark builder — dependency-free job groups (Section III).

A *job* is a mini-batch of one layer of one tenant model.  The host-side
control program chops the queue of jobs into dependency-free *groups*; jobs
within a group may be freely reordered (multi-tenancy + mini-batch
independence, per AI-MT's observation cited in the paper).

The benchmark interleaves jobs from all of a task's models round-robin,
which both mimics the multi-tenant arrival pattern and guarantees each group
mixes models (the situation MAGMA exploits).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Sequence

import numpy as np

from repro.costmodel.layers import LayerDesc
from repro.workloads.models import TASK_MODELS, model_layers


@dataclasses.dataclass(frozen=True)
class Job:
    uid: int
    model: str
    layer: LayerDesc

    @property
    def flops(self) -> int:
        return self.layer.flops


@dataclasses.dataclass(frozen=True)
class JobGroup:
    task: str
    jobs: tuple

    def __len__(self) -> int:
        return len(self.jobs)

    @property
    def total_flops(self) -> float:
        return float(sum(j.flops for j in self.jobs))


def build_task_groups(task: str, group_size: int = 100, num_groups: int = 1,
                      seed: int = 0) -> List[JobGroup]:
    """Round-robin interleave the task's model layers into groups.

    Different ``seed`` values rotate each model's starting layer, yielding
    distinct-but-same-distribution groups (used by the warm-start study).
    """
    models = TASK_MODELS[task]
    rng = np.random.default_rng(seed)
    streams = []
    for m in models:
        layers = model_layers(m)
        start = int(rng.integers(0, len(layers)))
        streams.append((m, itertools.cycle(layers[start:] + layers[:start])))

    groups, uid = [], 0
    for _ in range(num_groups):
        jobs = []
        for i in range(group_size):
            m, stream = streams[i % len(streams)]
            jobs.append(Job(uid, m, next(stream)))
            uid += 1
        groups.append(JobGroup(task, tuple(jobs)))
    return groups


def jobs_flops(jobs: Sequence[Job]) -> np.ndarray:
    return np.array([j.flops for j in jobs], dtype=np.float64)
