from repro.workloads.models import MODEL_ZOO, model_layers, TASK_MODELS
from repro.workloads.benchmark import Job, JobGroup, build_task_groups

__all__ = ["MODEL_ZOO", "model_layers", "TASK_MODELS",
           "Job", "JobGroup", "build_task_groups"]
