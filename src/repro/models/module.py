"""Minimal functional module system: param pytrees + logical sharding axes.

Params are nested dicts whose leaves are ``Param`` pytree nodes carrying a
tuple of *logical axis names* as static metadata (MaxText-style).  After
init, ``split`` separates the value tree from the axes tree; the axes tree
is mapped to concrete ``PartitionSpec``s by the rules in
``repro.dist.sharding``.

Everything is jit/eval_shape friendly — ``jax.eval_shape(init)`` yields the
same tree with ShapeDtypeStruct values, which is how the 512-device dry-run
builds sharded ShapeDtypeStructs without allocating.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    value: Any                      # jnp array or ShapeDtypeStruct
    axes: Tuple[Optional[str], ...]  # one logical name (or None) per dim

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def is_param(x) -> bool:
    return isinstance(x, Param)


def split(tree):
    """Param tree -> (value tree, axes tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def normal_init(key, shape, dtype, stddev: float = 0.02):
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def scaled_init(fan_in: int):
    def init(key, shape, dtype):
        return normal_init(key, shape, dtype, stddev=fan_in ** -0.5)
    return init


def zeros_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Splittable key stream so init code reads linearly."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, k = jax.random.split(self._key)
        return k


def param(keygen: KeyGen, shape, axes, dtype=jnp.bfloat16,
          init: Callable = None, stddev: float = 0.02) -> Param:
    assert len(shape) == len(axes), (shape, axes)
    if init is None:
        value = normal_init(keygen(), shape, dtype, stddev)
    else:
        value = init(keygen(), shape, dtype)
    return Param(value, tuple(axes))


def scan_or_unroll(body, carry, xs, use_scan: bool = True):
    """``lax.scan`` or a python-unrolled equivalent (same signature).

    Unrolling exists for the dry-run's cost analysis: XLA's HloCostAnalysis
    visits a while-loop body once, so FLOPs of scanned layers are invisible;
    lowering the unrolled variant exposes them (see launch.roofline)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked


def count_params(values) -> int:
    return sum(int(jnp.size(v)) if not isinstance(v, jax.ShapeDtypeStruct)
               else int(jnp.prod(jnp.array(v.shape)))
               for v in jax.tree.leaves(values))
