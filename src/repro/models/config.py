"""Unified model configuration for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'
    num_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 0
    sliding_window: int = 0      # 0 = full attention
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_ff: int = 0           # routed expert hidden dim
    capacity_factor: float = 1.25
    # SSM (mamba1/mamba2)
    ssm_state: int = 0
    d_inner: int = 0             # 0 -> 2 * d_model
    conv_width: int = 4
    dt_rank: int = 0             # mamba1; 0 -> ceil(d_model / 16)
    ssm_head_dim: int = 64       # mamba2
    ssm_chunk: int = 128
    # XLA time-scan chunking: unroll this many recurrence steps per scan
    # iteration so the chain fuses (0 = plain per-step scan); the Pallas
    # ssm_scan kernel (use_flash) supersedes this on TPU
    ssm_time_chunk: int = 0
    # hybrid (zamba2): one weight-tied attention block applied every k layers
    shared_attn_every: int = 0
    # enc-dec
    encoder_layers: int = 0
    # modality frontend stubs ([audio]/[vlm]): prepended precomputed embeds
    num_prefix_embeds: int = 0
    # attention memory control: process queries in chunks of this size when
    # S > 2*chunk (exact, O(S*chunk) memory; SWA also slices the KV range)
    attn_q_chunk: int = 1024
    # decode MoE: route the whole (B*S) token stream as one group (EP
    # all-to-all) instead of per-row capacity — see layers.moe
    moe_group_decode: bool = False
    # fused cross-entropy: never materialize (B, S, V) logits; process the
    # sequence in chunks of this size (0 = off)
    ce_seq_chunk: int = 0
    # attention batch re-sharding: run attention with the batch sharded over
    # BOTH (data, model) and heads replicated — removes contraction-dim TP
    # all-reduces for archs whose head count does not divide the model axis
    attn_batch_shard: bool = False
    # FSDP: shard weights' embed dim over 'data' (ZeRO-3).  Models whose
    # (params + optimizer state) fit replicated can turn this off to remove
    # the per-layer all-gathers entirely.
    fsdp: bool = True
    # numerics / lowering
    dtype: str = "bfloat16"
    scan_layers: bool = True     # scan over layers (False = unrolled, used by
                                 # the dry-run for exact cost_analysis)
    use_flash: bool = False      # route attention through the Pallas kernel
    remat: bool = True

    # ---- derived -----------------------------------------------------------
    @property
    def dtype_jnp(self):
        return jnp.dtype(self.dtype)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def n_ssm_heads(self) -> int:
        return self.inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
