from repro.models.config import ModelConfig
from repro.models.registry import get_model, MODEL_FAMILIES

__all__ = ["ModelConfig", "get_model", "MODEL_FAMILIES"]
