"""Architecture registry: config -> model object, per-arch sharding rules,
dry-run input specs, and analytic FLOPs/param counts for the roofline.

The 10 assigned architectures are declared in ``repro.configs``; this module
is the single place that knows which family class serves which config and
how each (shape x arch) cell is lowered.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeConfig, SHAPES
from repro.models import module
from repro.models.transformer import TransformerLM, EncDecLM
from repro.models.mamba import MambaLM, HybridLM

MODEL_FAMILIES = {
    "dense": TransformerLM,
    "moe": TransformerLM,
    "vlm": TransformerLM,
    "encdec": EncDecLM,
    "ssm": MambaLM,
    "hybrid": HybridLM,
}


def get_model(cfg: ModelConfig):
    return MODEL_FAMILIES[cfg.family](cfg)


# ---------------------------------------------------------------------------
# per-arch sharding rule overrides (divisibility-driven)
# ---------------------------------------------------------------------------
def sharding_rules(cfg: ModelConfig, model_axis: int = 16) -> Dict[str, object]:
    """Pick TP axes that divide this arch's dims.

    - heads: shard over 'model' when divisible (all archs but phi3);
      otherwise shard head_dim (phi3: 40 heads, hd=128 -> contraction-dim TP).
    - kv_heads: shard when divisible (qwen/moonshot/seamless kv=16);
      otherwise replicated (kv projections are small).
    """
    rules: Dict[str, object] = {}
    if not cfg.fsdp:
        rules["embed"] = None      # replicate weights across 'data'
    if cfg.attn_batch_shard:
        rules["attn_batch"] = ("pod", "data", "model")
        rules["heads"] = None
        rules["head_dim"] = None
    elif cfg.n_heads and cfg.n_heads % model_axis != 0:
        rules["heads"] = None
        if cfg.hd % model_axis == 0:
            rules["head_dim"] = "model"
    if cfg.n_kv_heads and cfg.n_kv_heads % model_axis == 0:
        rules["kv_heads"] = "model"
    return rules


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if (arch, shape) is runnable, else the documented skip reason."""
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")
                         or cfg.sliding_window > 0)
        if not sub_quadratic:
            return ("full quadratic attention; long_500k requires a "
                    "sub-quadratic path (skip per assignment)")
    return None


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype_jnp
    if cfg.family == "vlm":
        P = cfg.num_prefix_embeds
        return {"embeds": _sds((B, P, cfg.d_model), dt),
                "tokens": _sds((B, S - P), jnp.int32),
                "labels": _sds((B, S - P), jnp.int32)}
    if cfg.family == "encdec":
        return {"frames": _sds((B, S, cfg.d_model), dt),
                "tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32)}
    return {"tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32)}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype_jnp
    if cfg.family == "vlm":
        P = cfg.num_prefix_embeds
        return {"embeds": _sds((B, P, cfg.d_model), dt),
                "tokens": _sds((B, S - P), jnp.int32)}
    if cfg.family == "encdec":
        return {"frames": _sds((B, S, cfg.d_model), dt)}
    return {"tokens": _sds((B, S), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, model=None):
    """(cache_specs, tokens_spec, pos_spec) for one decode step."""
    model = model or get_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        values_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        values_sds, _ = module.split(values_sds)
        frames = _sds((B, cfg.num_prefix_embeds, cfg.d_model), cfg.dtype_jnp)
        cache = jax.eval_shape(lambda v, f: model.init_cache(v, f, S),
                               values_sds, frames)
    else:
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
    tokens = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return cache, tokens, pos


# ---------------------------------------------------------------------------
# analytic model FLOPs (the roofline's MODEL_FLOPS = 6 N D term)
# ---------------------------------------------------------------------------
def count_params(cfg: ModelConfig) -> int:
    model = get_model(cfg)
    tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    values, _ = module.split(tree)
    return int(sum(np.prod(v.shape) for v in jax.tree.leaves(values)))


def count_active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k of n_experts routed)."""
    total = count_params(cfg)
    if cfg.n_experts == 0:
        return total
    model = get_model(cfg)
    tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    values, _ = module.split(tree)
    moe_leaf_names = ("w_gate", "w_up", "w_down")
    routed = 0
    lyr = values["layers"]
    if "moe" in lyr:
        for name in moe_leaf_names:
            routed += int(np.prod(getattr(lyr["moe"], name).shape))
    active_routed = routed * cfg.top_k / max(cfg.n_experts, 1)
    return int(total - routed + active_routed)


def model_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Model-essential HBM bytes per step — the memory-roofline floor.

    train:   AdamW update touches every param: read p(bf16) + m,v(f32),
             write same -> 20 B/param; plus grads r/w (4+4) and the
             per-layer checkpointed activations (write fwd + read bwd).
    decode:  read active params (bf16) once per token + read the KV/SSM
             state once; write one KV slot (negligible).
    prefill: read params once + stream activations through every layer.
    """
    n_total = count_params(cfg)
    n_active = count_active_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    n_layers = cfg.num_layers + cfg.encoder_layers
    if shape.kind == "train":
        act = 2 * 2 * B * S * d * n_layers          # ckpt stack w + r, bf16
        return float(28.0 * n_total + act)
    if shape.kind == "prefill":
        act = 2 * 2 * B * S * d * n_layers
        return float(2.0 * n_total + act)
    # decode: params + full KV/state read per emitted token
    if cfg.n_heads and cfg.family not in ("ssm",):
        eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
        n_attn = (math.ceil(cfg.num_layers / cfg.shared_attn_every)
                  if cfg.family == "hybrid" else n_layers)
        kv = 2 * n_attn * B * eff * max(cfg.n_kv_heads, 1) * cfg.hd * 2
    else:
        kv = 0.0
    if cfg.family in ("ssm", "hybrid"):
        kv += cfg.num_layers * B * cfg.inner * cfg.ssm_state * 4
    return float(2.0 * n_active + kv)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6 * N_active * tokens (train) or 2 * N_active * tokens (inference),
    plus the quadratic attention term where applicable."""
    n_active = count_active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    flops = mult * n_active * tokens
    # attention score/context FLOPs (not in the 6N rule)
    if cfg.n_heads:
        S = shape.seq_len
        eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
        if shape.kind == "decode":
            att = 2 * 2 * shape.global_batch * cfg.n_heads * cfg.hd * eff
        else:
            att = 2 * 2 * shape.global_batch * cfg.n_heads * cfg.hd * S * eff / 2
        n_attn_layers = (cfg.num_layers + cfg.encoder_layers
                         if cfg.family == "encdec" else
                         (math.ceil(cfg.num_layers / cfg.shared_attn_every)
                          if cfg.family == "hybrid" else cfg.num_layers))
        flops += (3.0 if shape.kind == "train" else 1.0) * att * n_attn_layers
    return float(flops)
