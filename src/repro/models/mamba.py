"""Mamba-1 (falcon-mamba), Mamba-2 blocks, and the Zamba2 hybrid
(Mamba-2 backbone + one weight-tied shared attention block applied every
``shared_attn_every`` layers).

The selective scan has three implementations:
  - ``selective_scan``      lax.scan over time (reference; used for train /
                            prefill on any backend),
  - ``kernels/ssm_scan``    Pallas TPU chunked kernel (opt-in via
                            ``cfg.use_flash``),
  - a single-step update for decode (state carried in the cache).

State convention: h (B, d_inner, N) float32;
  h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t ;  y_t = <h_t, C_t>.
Mamba-2 reuses the same recurrence with per-head scalar A broadcast over
channels and head-shared dt.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.module import KeyGen, Param, param, ones_init, scan_or_unroll, zeros_init


# ---------------------------------------------------------------------------
# selective scan (shared by mamba1/mamba2)
# ---------------------------------------------------------------------------
def selective_scan(x, dt, A, B, C, h0=None):
    """x, dt: (Bt, S, Di); A: (Di, N); B, C: (Bt, S, N) -> (y, h_final)."""
    Bt, S, Di = x.shape
    N = A.shape[1]
    h0 = jnp.zeros((Bt, Di, N), jnp.float32) if h0 is None else h0
    Af = A.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(dt_t[..., None] * Af[None])          # (Bt, Di, N)
        h = decay * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.sum(h * C_t[:, None, :], axis=-1)            # (Bt, Di)
        return h, y

    xs = (jnp.swapaxes(x.astype(jnp.float32), 0, 1),
          jnp.swapaxes(dt.astype(jnp.float32), 0, 1),
          jnp.swapaxes(B.astype(jnp.float32), 0, 1),
          jnp.swapaxes(C.astype(jnp.float32), 0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(ys, 0, 1), h


def selective_scan_chunked(x, dt, A, B, C, h0=None, chunk: int = 16):
    """Selective scan with the time axis processed ``chunk`` steps per
    lax.scan iteration, the inner steps unrolled straight-line.

    Numerically identical to ``selective_scan`` (same op order), but XLA
    fuses each unrolled chain into one kernel: per-step intermediates stay
    on-chip, the carried state is read/written once per *chunk* instead of
    once per step, and the while-loop trip count drops S -> S/chunk.  This
    is the pure-XLA mitigation of the SSM time-scan HBM wall (the full fix
    is the Pallas ``ssm_scan`` kernel, which also keeps the state in VMEM
    across chunks)."""
    Bt, S, Di = x.shape
    if S % chunk != 0:
        return selective_scan(x, dt, A, B, C, h0)
    N = A.shape[1]
    h0 = jnp.zeros((Bt, Di, N), jnp.float32) if h0 is None else h0
    Af = A.astype(jnp.float32)

    def to_chunks(a):
        t = jnp.swapaxes(a.astype(jnp.float32), 0, 1)   # (S, Bt, ...)
        return t.reshape((S // chunk, chunk) + t.shape[1:])

    xs = (to_chunks(x), to_chunks(dt), to_chunks(B), to_chunks(C))

    def body(h, inp):
        xc, dtc, Bc, Cc = inp                      # (chunk, Bt, ...)
        ys = []
        for t in range(chunk):                     # unrolled -> one fusion
            decay = jnp.exp(dtc[t][..., None] * Af[None])
            h = decay * h + (dtc[t] * xc[t])[..., None] * Bc[t][:, None, :]
            ys.append(jnp.sum(h * Cc[t][:, None, :], axis=-1))
        return h, jnp.stack(ys)

    h, ys = jax.lax.scan(body, h0, xs)
    return jnp.swapaxes(ys.reshape(S, Bt, Di), 0, 1), h


def selective_step(h, x_t, dt_t, A, B_t, C_t):
    """One decode step: x_t, dt_t (Bt, Di); B_t, C_t (Bt, N)."""
    decay = jnp.exp(dt_t[..., None].astype(jnp.float32) * A.astype(jnp.float32)[None])
    h = decay * h + (dt_t * x_t)[..., None].astype(jnp.float32) * B_t[:, None, :].astype(jnp.float32)
    y = jnp.sum(h * C_t[:, None, :].astype(jnp.float32), axis=-1)
    return h, y


def causal_conv1d(x, w, b):
    """Depthwise causal conv: x (Bt,S,Di), w (Di,W), b (Di,)."""
    W = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[None, None, :, i].squeeze(1)
              for i in range(W))
    return out + b[None, None]


def conv1d_step(conv_state, x_t, w, b):
    """conv_state: (Bt, W-1, Di) trailing inputs; x_t: (Bt, Di)."""
    full = jnp.concatenate([conv_state, x_t[:, None]], axis=1)   # (Bt, W, Di)
    out = jnp.einsum("bwd,dw->bd", full, w) + b[None]
    return full[:, 1:], out


# ---------------------------------------------------------------------------
# Mamba-1 block (falcon-mamba)
# ---------------------------------------------------------------------------
class Mamba1Params(NamedTuple):
    norm: Param          # (L, d)
    in_proj: Param       # (L, d, 2*Di)
    conv_w: Param        # (L, Di, W)
    conv_b: Param        # (L, Di)
    x_proj: Param        # (L, Di, dt_rank + 2N)
    dt_w: Param          # (L, dt_rank, Di)
    dt_b: Param          # (L, Di)
    A_log: Param         # (L, Di, N)
    D: Param             # (L, Di)
    out_proj: Param      # (L, Di, d)


def init_mamba1(kg: KeyGen, cfg: ModelConfig) -> Mamba1Params:
    Lr, d, Di, N = cfg.num_layers, cfg.d_model, cfg.inner, cfg.ssm_state
    dtr, W, dt = cfg.dtr, cfg.conv_width, cfg.dtype_jnp

    def a_init(key, shape, dtype):
        a = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32),
                             shape)
        return jnp.log(a).astype(dtype)

    return Mamba1Params(
        norm=L.init_rmsnorm(kg, Lr, d, dt),
        in_proj=param(kg, (Lr, d, 2 * Di), ("layers", "embed", "inner"), dt,
                      stddev=d ** -0.5),
        conv_w=param(kg, (Lr, Di, W), ("layers", "inner", "conv"), dt,
                     stddev=W ** -0.5),
        conv_b=param(kg, (Lr, Di), ("layers", "inner"), dt, init=zeros_init),
        x_proj=param(kg, (Lr, Di, dtr + 2 * N), ("layers", "inner", None), dt,
                     stddev=Di ** -0.5),
        dt_w=param(kg, (Lr, dtr, Di), ("layers", "dt_rank", "inner"), dt,
                   stddev=dtr ** -0.5),
        dt_b=param(kg, (Lr, Di), ("layers", "inner"), jnp.float32,
                   init=lambda k, s, _: jnp.log(
                       jnp.expm1(jnp.full(s, 1e-2, jnp.float32)))),
        A_log=param(kg, (Lr, Di, N), ("layers", "inner", "ssm_state"),
                    jnp.float32, init=a_init),
        D=param(kg, (Lr, Di), ("layers", "inner"), jnp.float32,
                init=ones_init),
        out_proj=param(kg, (Lr, Di, d), ("layers", "inner", "embed"), dt,
                       stddev=Di ** -0.5),
    )


def mamba1_block(lp: Mamba1Params, x, cfg: ModelConfig, state=None):
    """x: (Bt, S, d).  state=None: full scan (returns y, final_state);
    state=(conv_state, h): single-step decode (S==1)."""
    N, dtr = cfg.ssm_state, cfg.dtr
    h_in = L.rms_norm(lp.norm, x)
    xz = h_in @ lp.in_proj
    xz = constrain(xz, "batch", "seq", "inner")
    x_in, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        x_c = causal_conv1d(x_in, lp.conv_w, lp.conv_b)
        x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)
        dbc = x_c @ lp.x_proj
        dt_r, B_ssm, C_ssm = jnp.split(dbc, [dtr, dtr + N], axis=-1)
        dt = jax.nn.softplus((dt_r @ lp.dt_w).astype(jnp.float32)
                             + lp.dt_b[None, None])
        A = -jnp.exp(lp.A_log)
        if cfg.use_flash:
            from repro.kernels import ops as kops
            y, h_fin = kops.ssm_scan(x_c, dt, A, B_ssm, C_ssm)
        elif cfg.ssm_time_chunk:
            y, h_fin = selective_scan_chunked(x_c, dt, A, B_ssm, C_ssm,
                                              chunk=cfg.ssm_time_chunk)
        else:
            y, h_fin = selective_scan(x_c, dt, A, B_ssm, C_ssm)
        y = y + lp.D[None, None] * x_c.astype(jnp.float32)
        y = (y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
        out = constrain(y @ lp.out_proj, "batch", "seq", "embed")
        W = cfg.conv_width
        conv_tail = jnp.pad(x_in, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):, :]
        return x + out, (conv_tail, h_fin)

    conv_state, h = state
    x_t, z_t = x_in[:, 0], z[:, 0]
    conv_state, x_c = conv1d_step(conv_state, x_t, lp.conv_w, lp.conv_b)
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)
    dbc = x_c @ lp.x_proj
    dt_r, B_t, C_t = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus((dt_r @ lp.dt_w).astype(jnp.float32) + lp.dt_b[None])
    A = -jnp.exp(lp.A_log)
    h, y = selective_step(h, x_c, dt, A, B_t, C_t)
    y = y + lp.D[None] * x_c.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z_t.astype(jnp.float32)).astype(x.dtype)
    out = y[:, None] @ lp.out_proj
    return x + constrain(out, "batch", None, "embed"), (conv_state, h)


# ---------------------------------------------------------------------------
# Mamba-2 block (zamba2 backbone)
# ---------------------------------------------------------------------------
class Mamba2Params(NamedTuple):
    norm: Param          # (L, d)
    in_proj: Param       # (L, d, 2*Di)
    conv_w: Param        # (L, Di, W)
    conv_b: Param        # (L, Di)
    bc_proj: Param       # (L, d, 2N)
    dt_w: Param          # (L, d, H_ssm)
    dt_b: Param          # (L, H_ssm)
    A_log: Param         # (L, H_ssm)
    D: Param             # (L, Di)
    gate_norm: Param     # (L, Di)
    out_proj: Param      # (L, Di, d)


def init_mamba2(kg: KeyGen, n_layers: int, cfg: ModelConfig) -> Mamba2Params:
    d, Di, N = cfg.d_model, cfg.inner, cfg.ssm_state
    H, W, dt = cfg.n_ssm_heads, cfg.conv_width, cfg.dtype_jnp
    Lr = n_layers
    return Mamba2Params(
        norm=L.init_rmsnorm(kg, Lr, d, dt),
        in_proj=param(kg, (Lr, d, 2 * Di), ("layers", "embed", "inner"), dt,
                      stddev=d ** -0.5),
        conv_w=param(kg, (Lr, Di, W), ("layers", "inner", "conv"), dt,
                     stddev=W ** -0.5),
        conv_b=param(kg, (Lr, Di), ("layers", "inner"), dt, init=zeros_init),
        bc_proj=param(kg, (Lr, d, 2 * N), ("layers", "embed", None), dt,
                      stddev=d ** -0.5),
        dt_w=param(kg, (Lr, d, H), ("layers", "embed", None), dt,
                   stddev=d ** -0.5),
        dt_b=param(kg, (Lr, H), ("layers", None), jnp.float32,
                   init=lambda k, s, _: jnp.log(
                       jnp.expm1(jnp.full(s, 1e-2, jnp.float32)))),
        A_log=param(kg, (Lr, H), ("layers", None), jnp.float32,
                    init=lambda k, s, _: jnp.log(jnp.linspace(1.0, 16.0, s[-1])
                                                 )[None].repeat(s[0], 0)),
        D=param(kg, (Lr, Di), ("layers", "inner"), jnp.float32,
                init=ones_init),
        gate_norm=L.init_rmsnorm(kg, Lr, Di, dt),
        out_proj=param(kg, (Lr, Di, d), ("layers", "inner", "embed"), dt,
                       stddev=Di ** -0.5),
    )


def mamba2_block(lp: Mamba2Params, x, cfg: ModelConfig, state=None):
    """Mamba-2: scalar per-head decay; reuses the mamba1 recurrence with A
    and dt broadcast across each head's channels."""
    N, H, dh = cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    h_in = L.rms_norm(lp.norm, x)
    xz = constrain(h_in @ lp.in_proj, "batch", "seq", "inner")
    x_in, z = jnp.split(xz, 2, axis=-1)
    bc = h_in @ lp.bc_proj
    B_ssm, C_ssm = jnp.split(bc, 2, axis=-1)
    dt_h = jax.nn.softplus((h_in @ lp.dt_w).astype(jnp.float32)
                           + lp.dt_b[None, None])          # (Bt,S,H)
    A_h = -jnp.exp(lp.A_log)                               # (H,)
    A_full = jnp.repeat(A_h, dh)[:, None].repeat(N, 1)     # (Di, N)
    dt_full = jnp.repeat(dt_h, dh, axis=-1)                # (Bt,S,Di)

    if state is None:
        x_c = causal_conv1d(x_in, lp.conv_w, lp.conv_b)
        x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)
        if cfg.use_flash:
            from repro.kernels import ops as kops
            y, h_fin = kops.ssm_scan(x_c, dt_full, A_full, B_ssm, C_ssm)
        elif cfg.ssm_time_chunk:
            y, h_fin = selective_scan_chunked(x_c, dt_full, A_full, B_ssm,
                                              C_ssm,
                                              chunk=cfg.ssm_time_chunk)
        else:
            y, h_fin = selective_scan(x_c, dt_full, A_full, B_ssm, C_ssm)
        y = y + lp.D[None, None] * x_c.astype(jnp.float32)
        y = L.rms_norm(lp.gate_norm,
                       y.astype(x.dtype) *
                       jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
        out = constrain(y @ lp.out_proj, "batch", "seq", "embed")
        W = cfg.conv_width
        conv_tail = jnp.pad(x_in, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):, :]
        return x + out, (conv_tail, h_fin)

    conv_state, h = state
    x_t, z_t = x_in[:, 0], z[:, 0]
    conv_state, x_c = conv1d_step(conv_state, x_t, lp.conv_w, lp.conv_b)
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)
    h, y = selective_step(h, x_c, dt_full[:, 0], A_full, B_ssm[:, 0],
                          C_ssm[:, 0])
    y = y + lp.D[None] * x_c.astype(jnp.float32)
    y = L.rms_norm(lp.gate_norm,
                   y.astype(x.dtype) *
                   jax.nn.silu(z_t.astype(jnp.float32)).astype(x.dtype))
    out = y[:, None] @ lp.out_proj
    return x + constrain(out, "batch", None, "embed"), (conv_state, h)


# ---------------------------------------------------------------------------
# Falcon-mamba: pure Mamba-1 LM
# ---------------------------------------------------------------------------
class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.vocab_padded = L.pad_vocab(cfg.vocab)

    def init(self, key):
        cfg = self.cfg
        kg = KeyGen(key)
        dt = cfg.dtype_jnp
        return {
            "embed": L.init_embedding(kg, self.vocab_padded, cfg.d_model, dt),
            "layers": init_mamba1(kg, cfg),
            "final_norm": param(kg, (cfg.d_model,), ("embed",), dt,
                                init=ones_init),
        }

    def hidden_states(self, values, x, with_state=False):
        cfg = self.cfg

        def body(h, lp):
            h2, st = mamba1_block(lp, h, cfg)
            return h2, st if with_state else None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, states = scan_or_unroll(body, x, values["layers"], cfg.scan_layers)
        return L.rms_norm(values["final_norm"], x), states

    def _logits(self, values, h):
        logits = L.logits_head(values["embed"], h).astype(jnp.float32)
        if self.vocab_padded > self.cfg.vocab:
            pad = jnp.arange(self.vocab_padded) >= self.cfg.vocab
            logits = jnp.where(pad[None, None], -1e30, logits)
        return logits

    def loss(self, values, batch):
        x = L.embed(values["embed"], batch["tokens"])
        h, _ = self.hidden_states(values, x)
        nll = L.nll_loss(values["embed"], h, batch["labels"], self.cfg.vocab,
                         self.vocab_padded, self.cfg.ce_seq_chunk)
        return nll, {"nll": nll, "aux": jnp.float32(0.0)}

    def init_cache(self, batch: int, seq_len: int):
        cfg = self.cfg
        Lr, Di, N, W = cfg.num_layers, cfg.inner, cfg.ssm_state, cfg.conv_width
        return {
            "conv": jnp.zeros((Lr, batch, W - 1, Di), cfg.dtype_jnp),
            "ssm": jnp.zeros((Lr, batch, Di, N), jnp.float32),
        }

    def prefill(self, values, batch, seq_len: int):
        x = L.embed(values["embed"], batch["tokens"])
        h, states = self.hidden_states(values, x, with_state=True)
        cache = {"conv": states[0], "ssm": states[1]}
        return self._logits(values, h[:, -1:]), cache

    def decode_step(self, values, cache, tokens, cur_pos):
        cfg = self.cfg
        x = L.embed(values["embed"], tokens)

        def body(h, xs):
            lp, conv, ssm = xs
            h2, (nconv, nssm) = mamba1_block(lp, h, cfg, state=(conv, ssm))
            return h2, (nconv, nssm)

        h, (nconv, nssm) = scan_or_unroll(
            body, x, (values["layers"], cache["conv"], cache["ssm"]),
            cfg.scan_layers)
        h = L.rms_norm(values["final_norm"], h)
        return self._logits(values, h), {"conv": nconv, "ssm": nssm}


# ---------------------------------------------------------------------------
# Zamba2 hybrid: Mamba-2 backbone + weight-tied shared attention block
# ---------------------------------------------------------------------------
class HybridLM:
    """``shared_attn_every`` mamba2 layers are preceded by one application of
    a single weight-tied (attention + MLP) block; each application keeps its
    own KV cache."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.vocab_padded = L.pad_vocab(cfg.vocab)
        k = cfg.shared_attn_every
        self.n_apps = math.ceil(cfg.num_layers / k)
        # group g covers mamba layers [g*k, min((g+1)*k, L))
        self.group_sizes = [min((g + 1) * k, cfg.num_layers) - g * k
                            for g in range(self.n_apps)]

    def init(self, key):
        cfg = self.cfg
        kg = KeyGen(key)
        dt = cfg.dtype_jnp
        shared = {
            "attn_norm": param(kg, (cfg.d_model,), ("embed",), dt,
                               init=ones_init),
            "attn": L.init_attention(kg, 1, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.hd, dt),
            "mlp_norm": param(kg, (cfg.d_model,), ("embed",), dt,
                              init=ones_init),
            "mlp": L.init_mlp(kg, 1, cfg.d_model, cfg.d_ff, dt),
        }
        return {
            "embed": L.init_embedding(kg, self.vocab_padded, cfg.d_model, dt),
            "layers": init_mamba2(kg, cfg.num_layers, cfg),
            "shared": shared,
            "final_norm": param(kg, (cfg.d_model,), ("embed",), dt,
                                init=ones_init),
        }

    def _shared_slice(self, values):
        sh = values["shared"]
        return {
            "attn_norm": sh["attn_norm"],
            "attn": jax.tree.map(lambda a: a[0], sh["attn"]),
            "mlp_norm": sh["mlp_norm"],
            "mlp": jax.tree.map(lambda a: a[0], sh["mlp"]),
        }

    def _apply_shared_full(self, sh, h):
        cfg = self.cfg
        hn = L.rms_norm(sh["attn_norm"], h)
        h = h + L.full_attention(sh["attn"], None, hn, n_heads=cfg.n_heads,
                                 n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                                 rope_theta=cfg.rope_theta,
                                 use_flash=cfg.use_flash,
                                 q_chunk=cfg.attn_q_chunk)
        hn = L.rms_norm(sh["mlp_norm"], h)
        return h + L.mlp(sh["mlp"], hn)

    def hidden_states(self, values, x):
        cfg = self.cfg
        sh = self._shared_slice(values)

        def mamba_body(h, lp):
            h2, _ = mamba2_block(lp, h, cfg)
            return h2, None

        if cfg.remat:
            mamba_body = jax.checkpoint(mamba_body)
        off = 0
        for g, size in enumerate(self.group_sizes):
            x = self._apply_shared_full(sh, x)
            grp = jax.tree.map(lambda a: a[off:off + size], values["layers"])
            x, _ = scan_or_unroll(mamba_body, x, grp, cfg.scan_layers)
            off += size
        return L.rms_norm(values["final_norm"], x)

    def _logits(self, values, h):
        logits = L.logits_head(values["embed"], h).astype(jnp.float32)
        if self.vocab_padded > self.cfg.vocab:
            pad = jnp.arange(self.vocab_padded) >= self.cfg.vocab
            logits = jnp.where(pad[None, None], -1e30, logits)
        return logits

    def loss(self, values, batch):
        x = L.embed(values["embed"], batch["tokens"])
        h = self.hidden_states(values, x)
        nll = L.nll_loss(values["embed"], h, batch["labels"], self.cfg.vocab,
                         self.vocab_padded, self.cfg.ce_seq_chunk)
        return nll, {"nll": nll, "aux": jnp.float32(0.0)}

    def init_cache(self, batch: int, seq_len: int):
        cfg = self.cfg
        Lr, Di, N, W = cfg.num_layers, cfg.inner, cfg.ssm_state, cfg.conv_width
        one = L.init_kv_cache(batch, seq_len, cfg.n_kv_heads, cfg.hd,
                              cfg.dtype_jnp)
        kv = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_apps,) + a.shape).copy(), one)
        return {
            "conv": jnp.zeros((Lr, batch, W - 1, Di), cfg.dtype_jnp),
            "ssm": jnp.zeros((Lr, batch, Di, N), jnp.float32),
            "kv": kv,
        }

    def decode_step(self, values, cache, tokens, cur_pos):
        cfg = self.cfg
        sh = self._shared_slice(values)
        x = L.embed(values["embed"], tokens)

        def mamba_body(h, xs):
            lp, conv, ssm = xs
            h2, (nc, ns) = mamba2_block(lp, h, cfg, state=(conv, ssm))
            return h2, (nc, ns)

        new_conv, new_ssm, new_kv = [], [], []
        off = 0
        for g, size in enumerate(self.group_sizes):
            kv_g = jax.tree.map(lambda a: a[g], cache["kv"])
            hn = L.rms_norm(sh["attn_norm"], x)
            a_out, nkv = L.decode_attention(
                sh["attn"], hn, kv_g, cur_pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta)
            x = x + a_out
            hn = L.rms_norm(sh["mlp_norm"], x)
            x = x + L.mlp(sh["mlp"], hn)
            new_kv.append(nkv)

            grp = jax.tree.map(lambda a: a[off:off + size], values["layers"])
            conv_g = cache["conv"][off:off + size]
            ssm_g = cache["ssm"][off:off + size]
            x, (nc, ns) = scan_or_unroll(mamba_body, x,
                                         (grp, conv_g, ssm_g),
                                         cfg.scan_layers)
            new_conv.append(nc)
            new_ssm.append(ns)
            off += size

        h = L.rms_norm(values["final_norm"], x)
        cache_out = {
            "conv": jnp.concatenate(new_conv, axis=0),
            "ssm": jnp.concatenate(new_ssm, axis=0),
            "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv),
        }
        return self._logits(values, h), cache_out

    def prefill(self, values, batch, seq_len: int):
        """Full-sequence pass that fills SSM + KV caches."""
        cfg = self.cfg
        sh = self._shared_slice(values)
        x = L.embed(values["embed"], batch["tokens"])
        B = x.shape[0]

        def mamba_body(h, lp):
            h2, st = mamba2_block(lp, h, cfg)
            return h2, st

        convs, ssms, kvs = [], [], []
        off = 0
        for g, size in enumerate(self.group_sizes):
            hn = L.rms_norm(sh["attn_norm"], x)
            a_out, kv = L.prefill_attention(
                sh["attn"], hn, seq_len, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta, q_chunk=cfg.attn_q_chunk)
            x = x + a_out
            hn = L.rms_norm(sh["mlp_norm"], x)
            x = x + L.mlp(sh["mlp"], hn)
            kvs.append(kv)
            grp = jax.tree.map(lambda a: a[off:off + size], values["layers"])
            x, (nc, ns) = scan_or_unroll(mamba_body, x, grp,
                                         cfg.scan_layers)
            convs.append(nc)
            ssms.append(ns)
            off += size

        h = L.rms_norm(values["final_norm"], x[:, -1:])
        cache = {
            "conv": jnp.concatenate(convs, axis=0),
            "ssm": jnp.concatenate(ssms, axis=0),
            "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *kvs),
        }
        return self._logits(values, h), cache
