"""Decoder-only Transformer LM (dense / MoE / VLM-prefix) and the
encoder-decoder variant (seamless).  Scan-over-layers + remat throughout so
40-64-layer models lower to compact HLO that compiles quickly even at 512
partitions.

All ``apply`` functions take the *value* tree (params with ``Param``
wrappers stripped by ``module.split``).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.module import KeyGen, param, ones_init, scan_or_unroll, split


def _layer_norms(kg, n_layers, d, dtype, names):
    return {n: L.init_rmsnorm(kg, n_layers, d, dtype) for n in names}


class TransformerLM:
    """granite / danube / stablelm / phi3 / qwen2-moe / moonshot / llava."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.vocab_padded = L.pad_vocab(cfg.vocab)
        self.is_moe = cfg.n_experts > 0

    # -- init -----------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        kg = KeyGen(key)
        dt = cfg.dtype_jnp
        lyr = {
            "attn_norm": L.init_rmsnorm(kg, cfg.num_layers, cfg.d_model, dt),
            "attn": L.init_attention(kg, cfg.num_layers, cfg.d_model,
                                     cfg.n_heads, cfg.n_kv_heads, cfg.hd, dt),
            "mlp_norm": L.init_rmsnorm(kg, cfg.num_layers, cfg.d_model, dt),
        }
        if self.is_moe:
            pad_e = _pad_experts(cfg.n_experts)
            lyr["moe"] = L.init_moe(kg, cfg.num_layers, cfg.d_model,
                                    cfg.n_experts, cfg.expert_ff,
                                    cfg.n_shared_experts, dt,
                                    pad_experts_to=pad_e)
        else:
            lyr["mlp"] = L.init_mlp(kg, cfg.num_layers, cfg.d_model,
                                    cfg.d_ff, dt)
        return {
            "embed": L.init_embedding(kg, self.vocab_padded, cfg.d_model, dt),
            "layers": lyr,
            "final_norm": param(kg, (cfg.d_model,), ("embed",), dt,
                                init=ones_init),
        }

    # -- forward --------------------------------------------------------------
    def _block(self, lp, x, moe_group=False):
        cfg = self.cfg
        h = L.rms_norm(lp["attn_norm"], x)
        h = L.full_attention(lp["attn"], None, h, n_heads=cfg.n_heads,
                             n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                             rope_theta=cfg.rope_theta,
                             window=cfg.sliding_window,
                             use_flash=cfg.use_flash,
                             q_chunk=cfg.attn_q_chunk)
        x = x + h
        h = L.rms_norm(lp["mlp_norm"], x)
        if self.is_moe:
            h, aux = L.moe(lp["moe"], h, n_experts=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           group_tokens=moe_group)
        else:
            h, aux = L.mlp(lp["mlp"], h), jnp.float32(0.0)
        return x + h, aux

    def hidden_states(self, values, x):
        """Run the layer stack over embedded inputs x: (B, S, d)."""
        cfg = self.cfg

        def body(carry, lp):
            h, aux = carry
            h2, a = self._block(lp, h)
            return (h2, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       values["layers"])
        else:
            aux = jnp.float32(0.0)
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda p: p[i], values["layers"])
                (x, aux), _ = body((x, aux), lp)
        return L.rms_norm(values["final_norm"], x), aux

    def _logits(self, values, h):
        logits = L.logits_head(values["embed"], h).astype(jnp.float32)
        if self.vocab_padded > self.cfg.vocab:
            pad = jnp.arange(self.vocab_padded) >= self.cfg.vocab
            logits = jnp.where(pad[None, None], -1e30, logits)
        return logits

    def embed_inputs(self, values, batch):
        """tokens (B,S) and/or prefix 'embeds' (B,P,d) -> (B, S_total, d)."""
        parts = []
        if "embeds" in batch:                      # VLM/audio stub prefix
            parts.append(batch["embeds"].astype(self.cfg.dtype_jnp))
        if "tokens" in batch:
            parts.append(L.embed(values["embed"], batch["tokens"]))
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        return constrain(x, "batch", "seq", "embed")

    def loss(self, values, batch):
        """Next-token cross entropy.  batch: tokens (B,S) [+ embeds], labels
        (B, S_text) aligned to the token positions."""
        x = self.embed_inputs(values, batch)
        h, aux = self.hidden_states(values, x)
        labels = batch["labels"]
        S_text = labels.shape[1]
        h_text = h[:, -S_text:]                    # predictions for text slots
        nll = L.nll_loss(values["embed"], h_text, labels, self.cfg.vocab,
                         self.vocab_padded, self.cfg.ce_seq_chunk)
        return nll + 0.01 * aux, {"nll": nll, "aux": aux}

    # -- serving --------------------------------------------------------------
    def cache_capacity(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window and seq_len > cfg.sliding_window:
            return cfg.sliding_window
        return seq_len

    def init_cache(self, batch: int, seq_len: int):
        cfg = self.cfg
        cap = self.cache_capacity(seq_len)
        one = L.init_kv_cache(batch, cap, cfg.n_kv_heads, cfg.hd,
                              cfg.dtype_jnp)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(),
            one)

    def prefill(self, values, batch, seq_len: int):
        """Embed + run layers, filling the cache. Returns (last logits, cache)."""
        cfg = self.cfg
        x = self.embed_inputs(values, batch)
        cap = self.cache_capacity(seq_len)

        def body(h, lp):
            hn = L.rms_norm(lp["attn_norm"], h)
            a_out, new_c = L.prefill_attention(
                lp["attn"], hn, cap,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta, window=cfg.sliding_window,
                q_chunk=cfg.attn_q_chunk)
            h = h + a_out
            hn = L.rms_norm(lp["mlp_norm"], h)
            if self.is_moe:
                m_out, _ = L.moe(lp["moe"], hn, n_experts=cfg.n_experts,
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor)
            else:
                m_out = L.mlp(lp["mlp"], hn)
            return h + m_out, new_c

        if cfg.remat:
            body = jax.checkpoint(body)
        h, new_cache = scan_or_unroll(body, x, values["layers"],
                                      cfg.scan_layers)
        h = L.rms_norm(values["final_norm"], h[:, -1:])
        return self._logits(values, h), new_cache

    def decode_step(self, values, cache, tokens, cur_pos, moe_group=None):
        """tokens: (B, 1); cur_pos: scalar. -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        if moe_group is None:
            moe_group = cfg.moe_group_decode
        x = L.embed(values["embed"], tokens)
        x = constrain(x, "batch", None, "embed")

        def body(h, xs):
            lp, c = xs
            hn = L.rms_norm(lp["attn_norm"], h)
            a_out, nc = L.decode_attention(
                lp["attn"], hn, c, cur_pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta, window=cfg.sliding_window)
            h = h + a_out
            hn = L.rms_norm(lp["mlp_norm"], h)
            if self.is_moe:
                m_out, _ = L.moe(lp["moe"], hn, n_experts=cfg.n_experts,
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 group_tokens=moe_group)
            else:
                m_out = L.mlp(lp["mlp"], hn)
            return h + m_out, nc

        h, new_cache = scan_or_unroll(body, x, (values["layers"], cache),
                                      cfg.scan_layers)
        h = L.rms_norm(values["final_norm"], h)
        return self._logits(values, h), new_cache


def _pad_experts(n: int, multiple: int = 16) -> int:
    return int(math.ceil(n / multiple) * multiple)


# ---------------------------------------------------------------------------
# encoder-decoder (seamless-m4t): audio-frame encoder stub + text decoder
# ---------------------------------------------------------------------------
class EncDecLM:
    """Encoder over precomputed frame embeddings (the audio frontend is a
    stub per the assignment), decoder with self + cross attention."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.vocab_padded = L.pad_vocab(cfg.vocab)

    def init(self, key):
        cfg = self.cfg
        kg = KeyGen(key)
        dt = cfg.dtype_jnp
        Le, Ld = cfg.encoder_layers, cfg.num_layers

        def attn(n_l):
            return L.init_attention(kg, n_l, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, dt)

        enc = {
            "attn_norm": L.init_rmsnorm(kg, Le, cfg.d_model, dt),
            "attn": attn(Le),
            "mlp_norm": L.init_rmsnorm(kg, Le, cfg.d_model, dt),
            "mlp": L.init_mlp(kg, Le, cfg.d_model, cfg.d_ff, dt),
        }
        dec = {
            "attn_norm": L.init_rmsnorm(kg, Ld, cfg.d_model, dt),
            "attn": attn(Ld),
            "cross_norm": L.init_rmsnorm(kg, Ld, cfg.d_model, dt),
            "cross": attn(Ld),
            "mlp_norm": L.init_rmsnorm(kg, Ld, cfg.d_model, dt),
            "mlp": L.init_mlp(kg, Ld, cfg.d_model, cfg.d_ff, dt),
        }
        return {
            "embed": L.init_embedding(kg, self.vocab_padded, cfg.d_model, dt),
            "enc_layers": enc,
            "enc_norm": param(kg, (cfg.d_model,), ("embed",), dt,
                              init=ones_init),
            "dec_layers": dec,
            "final_norm": param(kg, (cfg.d_model,), ("embed",), dt,
                                init=ones_init),
        }

    def encode(self, values, frames):
        """frames: (B, Se, d) precomputed embeddings -> (B, Se, d)."""
        cfg = self.cfg
        x = constrain(frames.astype(cfg.dtype_jnp), "batch", "seq", "embed")
        positions = jnp.arange(x.shape[1])[None, :]

        def body(h, lp):
            hn = L.rms_norm(lp["attn_norm"], h)
            # bidirectional: causal=False
            a_out = L.full_attention(
                lp["attn"], None, hn, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta, causal=False,
                q_chunk=cfg.attn_q_chunk, use_flash=False)
            h = h + a_out
            hn = L.rms_norm(lp["mlp_norm"], h)
            return h + L.mlp(lp["mlp"], hn), None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = scan_or_unroll(body, x, values["enc_layers"], cfg.scan_layers)
        return L.rms_norm(values["enc_norm"], x)

    def _dec_block(self, lp, h, enc_kv, attn_fn):
        hn = L.rms_norm(lp["attn_norm"], h)
        a_out, extra = attn_fn(lp, hn)
        h = h + a_out
        hn = L.rms_norm(lp["cross_norm"], h)
        h = h + L.cross_attention(lp["cross"], hn, enc_kv,
                                  n_heads=self.cfg.n_heads,
                                  n_kv=self.cfg.n_kv_heads,
                                  head_dim=self.cfg.hd)
        hn = L.rms_norm(lp["mlp_norm"], h)
        return h + L.mlp(lp["mlp"], hn), extra

    def loss(self, values, batch):
        """batch: frames (B,Se,d), tokens (B,St), labels (B,St)."""
        cfg = self.cfg
        enc_out = self.encode(values, batch["frames"])
        x = L.embed(values["embed"], batch["tokens"])

        def body(h, lp):
            enc_kv = L.encode_cross_kv(lp["cross"], enc_out,
                                       n_kv=cfg.n_kv_heads, head_dim=cfg.hd)

            def self_attn(lp_, hn):
                return L.full_attention(
                    lp_["attn"], None, hn, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta,
                    q_chunk=cfg.attn_q_chunk), None

            h, _ = self._dec_block(lp, h, enc_kv, self_attn)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = scan_or_unroll(body, x, values["dec_layers"], cfg.scan_layers)
        h = L.rms_norm(values["final_norm"], x)
        nll = L.nll_loss(values["embed"], h, batch["labels"], cfg.vocab,
                         self.vocab_padded, cfg.ce_seq_chunk)
        return nll, {"nll": nll, "aux": jnp.float32(0.0)}

    # serving: cache = (self KV ring, precomputed cross KV)
    def init_cache(self, values, frames, seq_len: int):
        cfg = self.cfg
        B = frames.shape[0]
        enc_out = self.encode(values, frames)

        def cross_of_layer(lp):
            return L.encode_cross_kv(lp["cross"], enc_out,
                                     n_kv=cfg.n_kv_heads, head_dim=cfg.hd)

        cross = jax.vmap(cross_of_layer)(values["dec_layers"])
        one = L.init_kv_cache(B, seq_len, cfg.n_kv_heads, cfg.hd,
                              cfg.dtype_jnp)
        self_c = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(),
            one)
        return {"self": self_c, "cross": cross}

    def decode_step(self, values, cache, tokens, cur_pos):
        cfg = self.cfg
        x = L.embed(values["embed"], tokens)

        def body(h, xs):
            lp, c, cross_kv = xs

            def self_attn(lp_, hn):
                return L.decode_attention(
                    lp_["attn"], hn, c, cur_pos, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta)

            h, nc = self._dec_block(lp, h, cross_kv, self_attn)
            return h, nc

        h, new_self = scan_or_unroll(
            body, x, (values["dec_layers"], cache["self"], cache["cross"]),
            cfg.scan_layers)
        h = L.rms_norm(values["final_norm"], h)
        logits = L.logits_head(values["embed"], h).astype(jnp.float32)
        return logits, {"self": new_self, "cross": cache["cross"]}
