"""Shared neural building blocks for the 10 assigned architectures.

Pure-functional JAX: every layer is ``apply(params_values, x, ...)`` where
params were created by the matching ``init_*`` (stacked over layers by the
callers).  Activation shardings are annotated with logical axis names via
``repro.dist.constrain`` — no-ops without an active mesh, so the exact same
code runs 1-device smoke tests and the 512-device dry-run.

Attention supports:  GQA (n_kv_heads < n_heads), RoPE, causal masking,
sliding windows (danube/zamba long-context), cross-attention (seamless),
a unified ring-buffer KV cache for decode (full-attention caches are a ring
of capacity seq_len; SWA caches a ring of capacity window), and an optional
Pallas flash-attention path for TPU.

MoE implements per-group capacity routing with sort-free scatter dispatch
(positions via one-hot cumsum), so compiled HLO FLOPs reflect real expert
work instead of dense dispatch einsums.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.module import KeyGen, Param, param, ones_init, zeros_init


# ---------------------------------------------------------------------------
# norms / rotary
# ---------------------------------------------------------------------------
def init_rmsnorm(kg: KeyGen, layers: int, dim: int, dtype):
    return param(kg, (layers, dim), ("layers", "embed"), dtype, init=ones_init)


def rms_norm(scale, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
class AttnParams(NamedTuple):
    wq: Param      # (L, d, H*hd)
    wk: Param      # (L, d, Kh*hd)
    wv: Param      # (L, d, Kh*hd)
    wo: Param      # (L, H*hd, d)


def init_attention(kg: KeyGen, layers: int, d_model: int, n_heads: int,
                   n_kv: int, head_dim: int, dtype) -> AttnParams:
    std = d_model ** -0.5
    return AttnParams(
        wq=param(kg, (layers, d_model, n_heads * head_dim),
                 ("layers", "embed", "qkv"), dtype, stddev=std),
        wk=param(kg, (layers, d_model, n_kv * head_dim),
                 ("layers", "embed", "qkv"), dtype, stddev=std),
        wv=param(kg, (layers, d_model, n_kv * head_dim),
                 ("layers", "embed", "qkv"), dtype, stddev=std),
        wo=param(kg, (layers, n_heads * head_dim, d_model),
                 ("layers", "qkv", "embed"), dtype, stddev=std),
    )


class KVCache(NamedTuple):
    """Unified ring-buffer cache: capacity C = seq_len (full attention)
    or window (SWA).  ``pos`` holds the absolute position stored in each
    slot (-1 = empty); masking uses positions, so full and windowed caches
    share one code path."""
    k: jnp.ndarray        # (B, C, Kh, hd)
    v: jnp.ndarray        # (B, C, Kh, hd)
    pos: jnp.ndarray      # (B, C) int32


def init_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
                  dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        pos=jnp.full((batch, capacity), -1, jnp.int32),
    )


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def attention_scores(q, k, mask, dtype):
    """q: (B,Sq,H,hd), k: (B,Sk,Kh,hd) -> ctx weights (B,H,Sq,Sk) given
    additive-mask ``mask`` broadcastable to (B, 1|H, Sq, Sk)."""
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    group = H // Kh
    qg = q.reshape(B, Sq, Kh, group, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    logits = logits.reshape(B, Kh * group, Sq, -1)
    logits = logits + mask
    w = jax.nn.softmax(logits, axis=-1)
    return w.astype(dtype)


def attention_context(w, v):
    """w: (B,H,Sq,Sk), v: (B,Sk,Kh,hd) -> (B,Sq,H,hd)."""
    B, H, Sq, Sk = w.shape
    Kh = v.shape[2]
    group = H // Kh
    wg = w.reshape(B, Kh, group, Sq, Sk)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", wg.astype(jnp.float32),
                     v.astype(jnp.float32))
    return ctx.reshape(B, Sq, H, -1)


def causal_mask(sq: int, sk: int, window: int = 0,
                q_offset: int = 0) -> jnp.ndarray:
    """Additive (1, 1, Sq, Sk) mask.  window=0 -> plain causal."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    ok = kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, -1e30)[None, None]


def _chunked_attention(q, k, v, *, causal, window, q_chunk, dtype):
    """Exact attention with the query axis processed in chunks.

    Row-wise softmax is independent across queries, so per-chunk full-row
    softmax is exact (no online rescaling needed) while bounding the score
    buffer to (B, H, q_chunk, Sk).  With a sliding window the KV range per
    chunk is statically sliced to q_chunk + window columns, making SWA
    prefill/train linear in S.  Each chunk is rematerialized so the
    backward pass never stores a full (Sq, Sk) score tensor.
    """
    B, S, H, D = q.shape
    n = S // q_chunk
    use_kv_slice = bool(window) and window + q_chunk < S

    def one_chunk(i):
        q_i = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        if use_kv_slice:
            kv_len = q_chunk + window
            start = jnp.clip(i * q_chunk - window, 0, S - kv_len)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, kv_len, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, kv_len, axis=1)
            kpos = start + jnp.arange(kv_len)[None, :]
        else:
            k_i, v_i = k, v
            kpos = jnp.arange(k.shape[1])[None, :]
        qpos = i * q_chunk + jnp.arange(q_chunk)[:, None]
        ok = jnp.ones((q_chunk, kpos.shape[1]), bool)
        if causal:
            ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
        mask = jnp.where(ok, 0.0, -1e30)[None, None]
        w = attention_scores(q_i, k_i, mask, dtype)
        return attention_context(w, v_i).astype(dtype)

    chunks = jax.lax.map(jax.checkpoint(one_chunk), jnp.arange(n))
    return jnp.swapaxes(chunks, 0, 1).reshape(B, S, H, D)


def full_attention(p: AttnParams, li, x, *, n_heads, n_kv, head_dim,
                   rope_theta, window=0, positions=None, use_flash=False,
                   flash_interpret=True, causal=True, q_chunk=0):
    """Training/prefill self-attention over the full sequence.

    ``q_chunk`` > 0 and S > 2*q_chunk routes through exact chunked
    attention (memory O(S * q_chunk) instead of O(S^2)); ``use_flash``
    routes through the Pallas kernel instead (TPU).  ``li`` is unused
    (params come pre-sliced by the layer scan)."""
    wq, wk, wv, wo = p
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = _split_heads(x @ wq, n_heads, head_dim)
    k = _split_heads(x @ wk, n_kv, head_dim)
    v = _split_heads(x @ wv, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    q = constrain(q, "attn_batch", "seq", "heads", "head_dim")
    k = constrain(k, "attn_batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "attn_batch", "seq", "kv_heads", "head_dim")
    if use_flash:
        from repro.kernels import ops as kops
        ctx = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   interpret=flash_interpret)
    elif q_chunk and S > 2 * q_chunk and S % q_chunk == 0:
        ctx = _chunked_attention(q, k, v, causal=causal, window=window,
                                 q_chunk=q_chunk, dtype=x.dtype)
    else:
        mask = causal_mask(S, S, window) if causal else \
            jnp.zeros((1, 1, 1, S))
        w = attention_scores(q, k, mask, x.dtype)
        ctx = attention_context(w, v).astype(x.dtype)
    ctx = constrain(ctx, "batch", "seq", "heads", "head_dim")
    out = ctx.reshape(B, S, n_heads * head_dim) @ wo
    return constrain(out, "batch", "seq", "embed")


def prefill_attention(p: AttnParams, x, capacity: int, *, n_heads, n_kv,
                      head_dim, rope_theta, window=0, q_chunk=0):
    """Full-sequence attention that also fills a fresh KV cache (ring
    layout, capacity ``capacity``)."""
    wq, wk, wv, wo = p
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q = _split_heads(x @ wq, n_heads, head_dim)
    k = _split_heads(x @ wk, n_kv, head_dim)
    v = _split_heads(x @ wv, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    if q_chunk and S > 2 * q_chunk and S % q_chunk == 0:
        ctx = _chunked_attention(q, k, v, causal=True, window=window,
                                 q_chunk=q_chunk, dtype=x.dtype)
    else:
        mask = causal_mask(S, S, window)
        w = attention_scores(q, k, mask, x.dtype)
        ctx = attention_context(w, v).astype(x.dtype)
    out = ctx.reshape(B, S, n_heads * head_dim) @ wo

    C = capacity
    if S >= C:
        # keep the last C entries
        kc, vc = k[:, S - C:], v[:, S - C:]
        pc = jnp.broadcast_to(jnp.arange(S - C, S, dtype=jnp.int32)[None],
                              (B, C))
        # ring alignment: entry at slot (pos % C)
        slots = pc[0] % C
        order = jnp.argsort(slots)
        new = KVCache(kc[:, order], vc[:, order], pc[:, order])
    else:
        pad = C - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pc = jnp.concatenate([
            jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)),
            jnp.full((B, pad), -1, jnp.int32)], axis=1)
        new = KVCache(kc, vc, pc)
    return constrain(out, "batch", "seq", "embed"), new


def decode_attention(p: AttnParams, x, cache: KVCache, cur_pos, *, n_heads,
                     n_kv, head_dim, rope_theta, window=0):
    """One-token decode: write (k,v) at slot cur_pos % C, attend over cache.

    x: (B, 1, d); cur_pos: scalar int32 (same position across batch)."""
    wq, wk, wv, wo = p
    B = x.shape[0]
    C = cache.k.shape[1]
    pos_b = jnp.full((B, 1), cur_pos, jnp.int32)
    q = _split_heads(x @ wq, n_heads, head_dim)
    k = _split_heads(x @ wk, n_kv, head_dim)
    v = _split_heads(x @ wv, n_kv, head_dim)
    q = apply_rope(q, pos_b, rope_theta)
    k = apply_rope(k, pos_b, rope_theta)

    slot = jnp.mod(cur_pos, C)
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    cp = jax.lax.dynamic_update_slice(cache.pos, pos_b, (0, slot))
    ck = constrain(ck, "batch", "kv_seq", "kv_heads", "head_dim")
    cv = constrain(cv, "batch", "kv_seq", "kv_heads", "head_dim")

    valid = (cp >= 0) & (cp <= cur_pos)
    if window:
        valid &= cp > cur_pos - window
    mask = jnp.where(valid, 0.0, -1e30)[:, None, None, :]   # (B,1,1,C)
    w = attention_scores(q, ck, mask, x.dtype)
    ctx = attention_context(w, cv).astype(x.dtype)
    out = ctx.reshape(B, 1, n_heads * head_dim) @ wo
    out = constrain(out, "batch", None, "embed")
    return out, KVCache(ck, cv, cp)


def cross_attention(p: AttnParams, x, enc_kv, *, n_heads, n_kv, head_dim):
    """Decoder->encoder cross attention (no rope, no mask over enc)."""
    wq, wk, wv, wo = p
    B, S, _ = x.shape
    q = _split_heads(x @ wq, n_heads, head_dim)
    k, v = enc_kv                                # precomputed (B, Se, Kh, hd)
    mask = jnp.zeros((1, 1, 1, k.shape[1]))
    w = attention_scores(q, k, mask, x.dtype)
    ctx = attention_context(w, v).astype(x.dtype)
    out = ctx.reshape(B, S, n_heads * head_dim) @ wo
    return constrain(out, "batch", "seq", "embed")


def encode_cross_kv(p: AttnParams, enc_out, *, n_kv, head_dim):
    k = _split_heads(enc_out @ p.wk, n_kv, head_dim)
    v = _split_heads(enc_out @ p.wv, n_kv, head_dim)
    return (k, v)


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU)
# ---------------------------------------------------------------------------
class MlpParams(NamedTuple):
    w_gate: Param    # (L, d, ff)
    w_up: Param      # (L, d, ff)
    w_down: Param    # (L, ff, d)


def init_mlp(kg: KeyGen, layers: int, d_model: int, d_ff: int, dtype) -> MlpParams:
    return MlpParams(
        w_gate=param(kg, (layers, d_model, d_ff), ("layers", "embed", "mlp"),
                     dtype, stddev=d_model ** -0.5),
        w_up=param(kg, (layers, d_model, d_ff), ("layers", "embed", "mlp"),
                   dtype, stddev=d_model ** -0.5),
        w_down=param(kg, (layers, d_ff, d_model), ("layers", "mlp", "embed"),
                     dtype, stddev=d_ff ** -0.5),
    )


def mlp(p: MlpParams, x):
    w_gate, w_up, w_down = p
    h = jax.nn.silu((x @ w_gate).astype(jnp.float32)).astype(x.dtype) * (x @ w_up)
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(h @ w_down, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Mixture of Experts (routed top-k, per-group capacity, scatter dispatch)
# ---------------------------------------------------------------------------
class MoeParams(NamedTuple):
    w_router: Param      # (L, d, E)
    w_gate: Param        # (L, E, d, ff)
    w_up: Param          # (L, E, d, ff)
    w_down: Param        # (L, E, ff, d)
    shared: Optional[MlpParams]   # shared experts as one fused MLP


def init_moe(kg: KeyGen, layers: int, d_model: int, n_experts: int,
             expert_ff: int, n_shared: int, dtype,
             pad_experts_to: int = 0) -> MoeParams:
    E = max(n_experts, pad_experts_to)
    std = d_model ** -0.5
    shared = None
    if n_shared:
        shared = init_mlp(kg, layers, d_model, n_shared * expert_ff, dtype)
    return MoeParams(
        w_router=param(kg, (layers, d_model, E), ("layers", "embed", None),
                       jnp.float32, stddev=std),
        w_gate=param(kg, (layers, E, d_model, expert_ff),
                     ("layers", "expert", "embed", "expert_mlp"), dtype, stddev=std),
        w_up=param(kg, (layers, E, d_model, expert_ff),
                   ("layers", "expert", "embed", "expert_mlp"), dtype, stddev=std),
        w_down=param(kg, (layers, E, expert_ff, d_model),
                     ("layers", "expert", "expert_mlp", "embed"), dtype,
                     stddev=expert_ff ** -0.5),
    shared=shared)


def moe(p: MoeParams, x, *, n_experts: int, top_k: int,
        capacity_factor: float = 1.25, group_tokens: bool = False):
    """Routed MoE.  x: (B, S, d) -> (y, aux_loss).

    Routing groups are batch rows; with ``group_tokens`` (decode
    optimization) the whole (B*S) token stream forms one routing group so
    expert capacity reflects the true token count instead of per-row
    worst case (an EP all-to-all moves tokens across the batch shards).
    Only the first ``n_experts`` experts are routable (padding experts for
    mesh divisibility receive -inf router logits).
    """
    w_router, w_gate, w_up, w_down, shared = p
    B, S, d = x.shape
    E = w_gate.shape[0]
    xg = x.reshape(1, B * S, d) if group_tokens else x
    G, T = xg.shape[0], xg.shape[1]

    logits = (xg.astype(jnp.float32) @ w_router)          # (G, T, E)
    if E > n_experts:
        pad_mask = jnp.arange(E) >= n_experts
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)            # (G, T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum(f_e * p_e)
    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(top_e, E).sum(2).mean(axis=(0, 1)) / top_k
    aux = n_experts * jnp.sum(me * ce)

    C = max(1, math.ceil(T * top_k * capacity_factor / n_experts))
    e_flat = top_e.reshape(G, T * top_k)                  # (G, TK)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)       # (G, TK, E)
    pos = jnp.cumsum(oh, axis=1) - oh                     # (G, TK, E)
    pos_sel = jnp.take_along_axis(pos, e_flat[..., None], -1)[..., 0]
    keep = pos_sel < C                                    # (G, TK)
    pos_cl = jnp.minimum(pos_sel, C - 1)

    x_rep = jnp.repeat(xg, top_k, axis=1)                 # (G, TK, d)

    def scatter_row(xr, er, pr, kr):
        buf = jnp.zeros((E, C, d), xr.dtype)
        return buf.at[er, pr].add(xr * kr[:, None].astype(xr.dtype))

    buf = jax.vmap(scatter_row)(x_rep, e_flat, pos_cl, keep)  # (G, E, C, d)
    buf = constrain(buf, "batch", "expert", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w_gate,
                               preferred_element_type=jnp.float32))
    h = (h.astype(x.dtype) * jnp.einsum("gecd,edf->gecf", buf, w_up))
    h = constrain(h, "batch", "expert", None, "expert_mlp")
    y_buf = jnp.einsum("gecf,efd->gecd", h, w_down)
    y_buf = constrain(y_buf, "batch", "expert", None, None)

    def gather_row(yb, er, pr, kr):
        return yb[er, pr] * kr[:, None].astype(yb.dtype)

    y_tok = jax.vmap(gather_row)(y_buf, e_flat, pos_cl, keep)  # (G, TK, d)
    y = (y_tok.reshape(G, T, top_k, d)
         * top_p[..., None].astype(y_tok.dtype)).sum(axis=2)
    y = y.reshape(B, S, d)
    if shared is not None:
        y = y + mlp(shared, x)
    return constrain(y, "batch", "seq", "embed"), aux


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------
def init_embedding(kg: KeyGen, vocab: int, d_model: int, dtype):
    return param(kg, (vocab, d_model), ("vocab", "embed"), dtype)


def embed(table, tokens):
    out = jnp.take(table, tokens, axis=0)
    return constrain(out, "batch", "seq", "embed")


def logits_head(table, x):
    """Tied LM head: (B, S, d) @ (V, d)^T -> (B, S, V)."""
    out = jnp.einsum("bsd,vd->bsv", x, table)
    return constrain(out, "batch", "seq", "vocab")


def pad_vocab(vocab: int, multiple: int = 128) -> int:
    return int(math.ceil(vocab / multiple) * multiple)


def nll_loss(table, h, labels, vocab: int, vocab_padded: int,
             seq_chunk: int = 0):
    """Next-token NLL.  With ``seq_chunk`` > 0 the (B, S, V) logits are
    never materialized: the sequence is processed in chunks, each chunk's
    logits/log-softmax live only inside a rematerialized map step — HBM
    traffic drops from O(B*S*V) to O(B*seq_chunk*V) per step (the
    'fused cross-entropy' memory optimization, see EXPERIMENTS §Perf)."""
    B, S, d = h.shape
    pad_mask = (jnp.arange(vocab_padded) >= vocab) if vocab_padded > vocab \
        else None

    def chunk_nll(h_i, lab_i):
        logits = logits_head(table, h_i).astype(jnp.float32)
        if pad_mask is not None:
            logits = jnp.where(pad_mask[None, None], -1e30, logits)
        lp = jax.nn.log_softmax(logits, axis=-1)
        tgt = jnp.take_along_axis(lp, lab_i[..., None], axis=-1)[..., 0]
        mask = (lab_i >= 0).astype(jnp.float32)
        return (tgt * mask).sum(), mask.sum()

    if seq_chunk and S > seq_chunk and S % seq_chunk == 0:
        def one(i):
            h_i = jax.lax.dynamic_slice_in_dim(h, i * seq_chunk, seq_chunk, 1)
            lab_i = jax.lax.dynamic_slice_in_dim(labels, i * seq_chunk,
                                                 seq_chunk, 1)
            return chunk_nll(h_i, lab_i)
        tot, cnt = jax.lax.map(jax.checkpoint(one),
                               jnp.arange(S // seq_chunk))
        return -tot.sum() / jnp.maximum(cnt.sum(), 1.0)
    tot, cnt = chunk_nll(h, labels)
    return -tot / jnp.maximum(cnt, 1.0)
