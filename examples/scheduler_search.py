"""Compare every Table-IV optimization method on one problem, with
convergence curves and the warm-start workflow.

Methods come from the ``repro.core.strategies`` registry: device-resident
strategies (magma + the black-box ports) run as one compiled scan each,
host-only methods (cmaes/tbpsa/RL/heuristics) run their own loops — all
behind the same ask/tell API and ``SearchResult`` contract.

    PYTHONPATH=src python examples/scheduler_search.py [--budget 2000]
"""
import argparse

import numpy as np

from repro.core import M3E
from repro.core.strategies import available, strategy_info
from repro.core.warmstart import WarmStartEngine
from repro.costmodel import get_setting
from repro.workloads import build_task_groups

GB = 1024 ** 3
METHODS = ["magma", "stdga", "de", "cmaes", "tbpsa", "pso", "random",
           "a2c", "ppo2", "herald_like", "ai_mt_like"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=2_000)
    ap.add_argument("--setting", default="S4")
    ap.add_argument("--bw", type=float, default=1.0)
    args = ap.parse_args()

    m3e = M3E(accel=get_setting(args.setting), bw_sys=args.bw * GB,
              warm_start=WarmStartEngine())
    groups = build_task_groups("Mix", group_size=100, num_groups=2, seed=0)

    assert set(METHODS) == set(available()), \
        "registry drifted from this demo's lineup"
    print(f"== ({args.setting}, Mix, BW={args.bw:g} GB/s), "
          f"budget {args.budget} ==")
    fits = {}
    for method in METHODS:
        kind = ("device" if strategy_info(method).device_resident
                else "host  ")
        res = m3e.search(groups[0], method=method, budget=args.budget,
                         seed=0)
        fits[method] = res.best_fitness
        curve = res.history_best
        pts = np.linspace(0, len(curve) - 1, 5).astype(int)
        spark = " -> ".join(f"{curve[i] / 1e9:.0f}" for i in pts)
        print(f"{method:12s} [{kind}] {res.best_fitness / 1e9:9.2f} "
              f"GFLOPs/s   [{spark}]   {res.wall_time_s:5.1f}s")
    best = max(fits, key=fits.get)
    print(f"\nbest method: {best}")

    # warm start onto a new group of the same task type (Table V workflow)
    warm = m3e.search(groups[1], method="magma", budget=100, seed=1)
    print(f"warm-started on a NEW group, 1 generation: "
          f"{warm.best_fitness / 1e9:.2f} GFLOPs/s "
          f"(vs full-search level {fits['magma'] / 1e9:.2f})")

    # device-resident scenario sweep, per strategy: a BW grid x 2 seeds
    # through repro.core.sweep — sharded across however many devices are
    # visible (try XLA_FLAGS=--xla_force_host_platform_device_count=8),
    # one vmapped XLA call per chunk (Fig. 12-style sweep, and the
    # Fig. 11 method-comparison workload when strategies vary)
    from repro.core.sweep import run_sweep
    import time
    bws = (0.5, 1.0, 4.0, 16.0)
    sweep_fits = [M3E(accel=get_setting(args.setting), bw_sys=b * GB
                      ).prepare(groups[0]) for b in bws]
    for name in ("magma", "de"):
        t0 = time.perf_counter()
        batch = run_sweep(sweep_fits, budget=args.budget, seeds=(0, 1),
                          strategy=name)
        dt = time.perf_counter() - t0
        print(f"\nbatched BW sweep, strategy={name} ({len(bws)} scenarios "
              f"x 2 seeds on {batch.num_devices} device(s), "
              f"{batch.num_chunks} compiled call(s), {dt:.1f}s):")
        for i, b in enumerate(bws):
            mean = batch.best_fitness[i].mean() / 1e9
            print(f"  BW={b:5.1f} GB/s   {mean:9.2f} GFLOPs/s")


if __name__ == "__main__":
    main()
