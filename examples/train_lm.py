"""End-to-end driver: train a ~100M-parameter granite-family LM for a few
hundred steps on the synthetic Markov stream, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]

(~100M params with the defaults; use --smoke for a 30-second CI run.)
The loss must fall well below the ln(V) i.i.d. entropy — the stream's
token-transition structure is learnable (see repro/train/data.py).
"""
import argparse

from repro.configs import get_config
from repro.models.registry import get_model
from repro.models.module import count_params
from repro.models import module
from repro.train.data import TokenStream
from repro.train.loop import TrainConfig, train

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=32_000)
    ap.add_argument("--ckpt-dir", default="ckpts/train_lm")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        args.d_model, args.layers, args.vocab = 64, 2, 512
        args.steps, args.seq = 40, 64

    cfg = get_config("granite-3-2b").replace(
        num_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 128, 1),
        d_ff=4 * args.d_model, vocab=args.vocab, dtype="float32")
    model = get_model(cfg)
    values, _ = module.split(model.init(jax.random.PRNGKey(0)))
    n = count_params(values)
    print(f"model: {cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab} "
          f"-> {n / 1e6:.1f}M params")

    stream = TokenStream(cfg, batch=args.batch, seq=args.seq, seed=0)
    tc = TrainConfig(lr=3e-4 if n > 5e7 else 3e-3,
                     warmup_steps=max(args.steps // 20, 5),
                     total_steps=args.steps)
    state = train(model, tc, stream, steps=args.steps,
                  checkpoint_dir=args.ckpt_dir, checkpoint_every=100,
                  log_every=10)
    eval_batch = stream.batch_at(10_000)
    loss = float(model.loss(state.params, eval_batch)[0])
    import math
    print(f"final eval loss {loss:.4f} (iid-entropy ceiling "
          f"{math.log(cfg.vocab):.2f})")


if __name__ == "__main__":
    main()
