"""Serve three tenant models with batched requests, scheduled by MAGMA.

    PYTHONPATH=src python examples/serve_multitenant.py

The engine decomposes requests into prefill/decode jobs, profiles each
(job x submesh) pair with the TPU cost model, searches the mapping with
MAGMA, prints the schedule + timeline against the manual baselines, and
then EXECUTES the schedule for real (greedy decoding on the smoke-size
models) to show end-to-end token generation.
"""
import numpy as np

from repro.launch.serve import build_tenants
from repro.serve.engine import MultiTenantEngine, default_submeshes


def main():
    tenants = build_tenants(["granite-3-2b", "qwen2-moe-a2.7b",
                             "falcon-mamba-7b"])
    engine = MultiTenantEngine(tenants, default_submeshes(), budget=2_000,
                               decode_window=8, seed=0)
    rng = np.random.default_rng(0)
    reqs = [(t.name, int(rng.integers(48, 128)), 16)
            for _ in range(4) for t in tenants]
    jobs = engine.jobs_for_requests(reqs)
    print(f"{len(reqs)} requests -> {len(jobs)} jobs "
          f"on {len(engine.submeshes)} submeshes\n")

    outs = {}
    for method in ("magma", "herald_like", "ai_mt_like"):
        outs[method] = engine.schedule(jobs, method=method)
        o = outs[method]
        print(f"{method:12s} makespan {o['makespan_s'] * 1e6:10.2f} us  "
              f"throughput {o['throughput_flops'] / 1e12:6.2f} TFLOP/s")

    best = outs["magma"]
    print("\nMAGMA submesh queues (job uids):")
    for sm, q in zip(engine.submeshes, best["queues"]):
        print(f"  {sm.name:8s} (tp={sm.tp:2d}): {q}")

    prompts = {j.uid: rng.integers(0, 256, (1, j.seq))
               for j in jobs if j.phase == "prefill"}
    gen = engine.execute(jobs, best["queues"], prompts)
    some = [j for j in jobs if j.phase == "decode"][0]
    print(f"\nexecuted {len(gen)} decode jobs; e.g. job {some.uid} "
          f"({some.tenant}) -> tokens {gen[some.uid][0, :8]}...")


if __name__ == "__main__":
    main()
