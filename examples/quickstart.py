"""Quickstart: schedule a multi-tenant job group with MAGMA in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's (Mix, S2 heterogeneous, BW=16 GB/s) problem, runs the
MAGMA search next to two manual baselines, and prints the found mapping.
"""
import sys

from repro.core import M3E
from repro.costmodel import get_setting
from repro.workloads import build_task_groups

GB = 1024 ** 3


def main():
    budget = int(sys.argv[1]) if len(sys.argv) > 1 else 2_000
    group = build_task_groups("Mix", group_size=60, seed=0)[0]
    m3e = M3E(accel=get_setting("S2"), bw_sys=16 * GB)

    print(f"group: {len(group)} jobs, {group.total_flops / 1e9:.1f} GFLOPs, "
          f"accelerator: {m3e.accel.describe()}")
    results = {}
    for method in ("magma", "herald_like", "ai_mt_like", "random"):
        res = m3e.search(group, method=method, budget=budget, seed=0)
        results[method] = res
        print(f"{method:12s} throughput = {res.best_fitness / 1e9:8.2f} "
              f"GFLOPs/s   ({res.n_samples} samples, "
              f"{res.wall_time_s:.2f} s)")

    best = results["magma"]
    print("\nMAGMA mapping (per-core job queues):")
    for a, queue in enumerate(m3e.describe_mapping(best)):
        sub = m3e.accel.sub_accels[a]
        print(f"  {sub.name:14s} ({sub.dataflow}): {queue}")


if __name__ == "__main__":
    main()
