"""Streaming multi-tenant scheduling: scenarios arrive, schedules stream out.

    PYTHONPATH=src python examples/streaming_service.py

Generates a bursty arrival trace over the paper's heavy/light DNN mixes
(AlphaGoZero/FasterRCNN/ResNet50 vs DeepSpeech2/NCF/Transformer) against
a heterogeneous accelerator, replays it through the streaming scheduler
(async analysis pool -> admission batching -> device sweep -> result
router), prints each schedule as it would stream out, and ends with the
service metrics.  Every schedule is bit-identical to a standalone
``magma_search`` with that (scenario, seed) — the demo checks one.

The second half replays an SLO-tagged trace (urgent/normal/batch
priority classes with per-class deadlines) through an anytime-mode
service: urgent deadline-carrying misses get an immediate short-budget
interim schedule while the full-budget refinement lands in the memo for
the next arrival.

Pass ``--trace-out trace.json`` to run the first service with the obs
layer on and drop a Chrome trace of every scenario's lifecycle spans —
open it at https://ui.perfetto.dev (schedules stay bit-identical; the
standalone re-check below still passes).
"""
import argparse

import numpy as np

from repro.core.magma import magma_search
from repro.memo import ScheduleMemo
from repro.obs import format_summary, read_trace, summarize
from repro.stream import (StreamConfig, StreamingScheduler, TraceConfig,
                          analyze_serial, generate_trace)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="enable tracing and write a Perfetto-loadable "
                         "Chrome trace of the first service run here")
    args = ap.parse_args(argv)
    trace_cfg = TraceConfig(
        num_scenarios=12, arrival="bursty", rate_hz=4.0, burst_size=3.0,
        mixes=("Heavy", "Light", "HeavyLight"), settings=("S2",),
        bw_ladder_gb=(4.0, 16.0, 64.0), group_size=32, batch_scale_max=8,
        seed=0)
    trace = generate_trace(trace_cfg)
    print(f"trace: {len(trace)} scenarios, bursty arrivals over "
          f"{trace[-1].arrival_s:.2f} s")
    for r in trace[:4]:
        print(f"  t={r.arrival_s:5.2f}s  uid={r.uid}  {r.mix:10s} "
              f"on {r.setting}  BW={r.bw_gb:g} GB/s  "
              f"batch x{r.batch_scale}")
    print("  ...")

    obs = {"enabled": True} if args.trace_out else None
    svc = StreamingScheduler(
        budget=1_000,
        stream=StreamConfig(batch_rows=4, analysis_workers=2, obs=obs))
    print("\nwarming executables (a long-lived service does this once)...")
    svc.warmup(trace)

    results = svc.run(trace)
    print("\nstreamed schedules:")
    for r in results:
        print(f"  uid={r.request.uid:2d}  {r.request.mix:10s} "
              f"best={r.best_fitness:9.3e}  "
              f"analysis {1e3 * (r.ready_s - r.analysis_start_s):5.1f} ms  "
              f"latency {1e3 * r.latency_s:6.1f} ms")

    m = svc.last_metrics
    print(f"\nservice: {m.scenarios_per_sec:.1f} scenarios/s sustained, "
          f"latency p50 {1e3 * m.latency_p50_s:.0f} ms / "
          f"p99 {1e3 * m.latency_p99_s:.0f} ms, "
          f"device idle {100 * m.device_idle_frac:.1f}%, "
          f"{m.num_batches} device batches "
          f"(fill {100 * m.mean_batch_fill:.0f}%)")

    # the guarantee: a streamed schedule == the standalone search
    check = results[0]
    fit = analyze_serial([check.request])[0].fit
    ref = magma_search(fit, budget=1_000, seed=check.request.seed)
    assert check.best_fitness == ref.best_fitness
    np.testing.assert_array_equal(check.best_accel, ref.best_accel)
    print(f"\nuid={check.request.uid} re-run standalone: bit-identical "
          f"(best={ref.best_fitness:.3e})")

    if args.trace_out:
        svc.export_trace(args.trace_out)
        spans = read_trace(args.trace_out)
        print(f"\nwrote {args.trace_out} ({len(spans)} spans — open at "
              f"https://ui.perfetto.dev)")
        print(format_summary(summarize(spans)))

    # --- SLO-aware admission + anytime schedules -----------------------
    slo_cfg = TraceConfig(
        num_scenarios=8, arrival="bursty", rate_hz=4.0, burst_size=4.0,
        mixes=("Light",), settings=("S2",), bw_ladder_gb=(16.0,),
        group_size=32, seed=1,
        priorities=("urgent", "normal", "batch", "batch"),
        slo_by_class=(("urgent", 0.3), ("normal", 0.6)))
    slo_trace = generate_trace(slo_cfg)
    slo_svc = StreamingScheduler(
        budget=1_000, memo=ScheduleMemo(),
        stream=StreamConfig(batch_rows=4, analysis_workers=2,
                            anytime_budget=250))
    print("\nSLO trace (urgent deadline 0.30 s, normal 0.60 s, "
          "anytime interim budget 250):")
    slo_svc.warmup(slo_trace)
    for r in slo_svc.run(slo_trace):
        dl = (f"deadline {r.request.deadline_s:.2f}s "
              f"{'MET ' if r.deadline_met else 'MISS'}"
              if r.request.deadline_s is not None else "no deadline     ")
        kind = "interim" if r.anytime_interim else "full   "
        print(f"  uid={r.request.uid:2d}  {r.request.priority:6s}  {dl}  "
              f"{kind} @budget {r.budget:4d}  "
              f"latency {1e3 * r.latency_s:6.1f} ms")
    sm = slo_svc.last_metrics
    print(f"SLO attainment {100 * sm.slo_attainment:.0f}% "
          f"({sm.deadline_misses}/{sm.num_with_deadline} misses), "
          f"urgent p99 {1e3 * sm.latency_p99_urgent_s:.0f} ms, "
          f"{sm.anytime_interims} interims refined to full budget in "
          f"the memo ({sm.anytime_refinements} refinements)")


if __name__ == "__main__":
    main()
