"""Streaming multi-tenant scheduling: scenarios arrive, schedules stream out.

    PYTHONPATH=src python examples/streaming_service.py

Generates a bursty arrival trace over the paper's heavy/light DNN mixes
(AlphaGoZero/FasterRCNN/ResNet50 vs DeepSpeech2/NCF/Transformer) against
a heterogeneous accelerator, replays it through the streaming scheduler
(async analysis pool -> admission batching -> device sweep -> result
router), prints each schedule as it would stream out, and ends with the
service metrics.  Every schedule is bit-identical to a standalone
``magma_search`` with that (scenario, seed) — the demo checks one.
"""
import numpy as np

from repro.core.magma import magma_search
from repro.stream import (StreamConfig, StreamingScheduler, TraceConfig,
                          analyze_serial, generate_trace)


def main():
    trace_cfg = TraceConfig(
        num_scenarios=12, arrival="bursty", rate_hz=4.0, burst_size=3.0,
        mixes=("Heavy", "Light", "HeavyLight"), settings=("S2",),
        bw_ladder_gb=(4.0, 16.0, 64.0), group_size=32, batch_scale_max=8,
        seed=0)
    trace = generate_trace(trace_cfg)
    print(f"trace: {len(trace)} scenarios, bursty arrivals over "
          f"{trace[-1].arrival_s:.2f} s")
    for r in trace[:4]:
        print(f"  t={r.arrival_s:5.2f}s  uid={r.uid}  {r.mix:10s} "
              f"on {r.setting}  BW={r.bw_gb:g} GB/s  "
              f"batch x{r.batch_scale}")
    print("  ...")

    svc = StreamingScheduler(
        budget=1_000,
        stream=StreamConfig(batch_rows=4, analysis_workers=2))
    print("\nwarming executables (a long-lived service does this once)...")
    svc.warmup(trace)

    results = svc.run(trace)
    print("\nstreamed schedules:")
    for r in results:
        print(f"  uid={r.request.uid:2d}  {r.request.mix:10s} "
              f"best={r.best_fitness:9.3e}  "
              f"analysis {1e3 * (r.ready_s - r.analysis_start_s):5.1f} ms  "
              f"latency {1e3 * r.latency_s:6.1f} ms")

    m = svc.last_metrics
    print(f"\nservice: {m.scenarios_per_sec:.1f} scenarios/s sustained, "
          f"latency p50 {1e3 * m.latency_p50_s:.0f} ms / "
          f"p99 {1e3 * m.latency_p99_s:.0f} ms, "
          f"device idle {100 * m.device_idle_frac:.1f}%, "
          f"{m.num_batches} device batches "
          f"(fill {100 * m.mean_batch_fill:.0f}%)")

    # the guarantee: a streamed schedule == the standalone search
    check = results[0]
    fit = analyze_serial([check.request])[0].fit
    ref = magma_search(fit, budget=1_000, seed=check.request.seed)
    assert check.best_fitness == ref.best_fitness
    np.testing.assert_array_equal(check.best_accel, ref.best_accel)
    print(f"\nuid={check.request.uid} re-run standalone: bit-identical "
          f"(best={ref.best_fitness:.3e})")


if __name__ == "__main__":
    main()
